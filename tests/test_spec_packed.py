"""Batched speculative decoding inside the unified engine.

Covers the PR's acceptance surface: greedy token identity spec-on vs
spec-off vs the batch-1 oracle (including under pool-pressure preemption
recompute and prefix-cache CoW forks), the one-dispatch/one-transfer-per-
step invariant with speculation on, the device-side rejection sampler
against a brute-force host oracle on shared uniforms (seeded sweep, plus
hypothesis when installed), the Monte-Carlo distribution guarantee, the
EngineMetrics speculative counters, precise refusals (config validation,
tp/pp sharding), and the ``batched_sync=False`` deprecation shim.
"""

import gc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.serving import (EngineConfig, Request, ServeEngine,
                           SpeculativeDecoder, rejection_accept)
from repro.serving.sampling import SamplingConfig
from repro.serving.sharded import validate_engine_sharding

from conftest import tiny_dense_spec


@pytest.fixture(scope="module", autouse=True)
def _fresh_caches():
    """This module sits after the heaviest serving modules in collection
    order; drop their accumulated jitted executables before building
    another dozen engines in the same process (XLA:CPU has been seen to
    segfault near the end of the full suite without this)."""
    gc.collect()
    jax.clear_caches()
    yield
    gc.collect()
    jax.clear_caches()


@pytest.fixture(scope="module")
def served():
    spec = tiny_dense_spec()
    model = build_model(spec, mesh=None, param_dtype=jnp.float32,
                        compute_dtype=jnp.float32)
    params = model.init(jax.random.key(7))
    return spec, model, params


@pytest.fixture(scope="module")
def drafted(served):
    """A draft that is a small perturbation of the target: its argmax
    agrees with the target's often but not always, so greedy runs exercise
    BOTH the accept path and the rejection/rollback path."""
    spec, model, params = served
    rng = np.random.default_rng(99)
    d_params = jax.tree_util.tree_map(
        lambda a: a * (1.0 + 0.04 * rng.standard_normal(a.shape)
                       .astype(np.float32)),
        params)
    return model, d_params


def _engine(model, params, n_spec=0, draft=None, **kw):
    cfg = EngineConfig(max_slots=kw.pop("max_slots", 3),
                       max_seq=kw.pop("max_seq", 96),
                       chunk_size=kw.pop("chunk_size", 4),
                       prefill_rows=kw.pop("prefill_rows", 2),
                       cache_layout="paged",
                       page_size=kw.pop("page_size", 8),
                       unified=True, n_spec=n_spec,
                       debug_guards=True, **kw)
    d_model, d_params = draft if draft else (None, None)
    return ServeEngine(model, params, cfg, rng=jax.random.key(11),
                       draft_model=d_model, draft_params=d_params)


def _greedy_reference(model, params, prompt, n, max_seq=128):
    cache = model.init_cache(1, max_seq)
    logits, cache = model.prefill(
        params, jnp.asarray([prompt], jnp.int32), cache=cache)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n - 1):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(logits[0])))
    return out


def _prompts(vocab, n, seed=0, lo=3, hi=14):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(0, vocab, size=rng.integers(lo,
                                                                      hi))]
            for _ in range(n)]


# ---------------------------------------------------------------------------
# greedy token identity
# ---------------------------------------------------------------------------

def test_greedy_identity_spec_on_off(served, drafted):
    """A *different* draft (rejections happen) must not change one greedy
    token vs the non-speculative unified engine and the step-by-step
    reference."""
    spec, model, params = served
    prompts = _prompts(spec.vocab, 5, seed=1)
    want = [
        [r.output for r in _engine(model, params).serve(
            [Request(prompt=list(p), max_new_tokens=10) for p in prompts])],
        [_greedy_reference(model, params, p, 10) for p in prompts],
    ]
    eng = _engine(model, params, n_spec=3, draft=drafted)
    reqs = eng.serve([Request(prompt=list(p), max_new_tokens=10)
                      for p in prompts])
    assert all(r.state == "done" for r in reqs)
    got = [r.output for r in reqs]
    assert got == want[0] == want[1]
    m = eng.metrics
    assert 0.0 < m.spec_acceptance_rate < 1.0  # real accept AND reject


def test_self_draft_accepts_everything(served):
    """Draft == target at temperature 0: every draft equals the target
    argmax, so every window fully accepts and earns its bonus token."""
    spec, model, params = served
    eng = _engine(model, params, n_spec=3, draft=(model, params))
    reqs = eng.serve([Request(prompt=list(p), max_new_tokens=12)
                      for p in _prompts(spec.vocab, 4, seed=2)])
    assert all(r.state == "done" for r in reqs)
    m = eng.metrics
    assert m.spec_acceptance_rate == 1.0
    assert m.spec_tokens_per_round == 4.0  # K+1 per window
    assert m.spec_bonus == m.spec_slot_rounds


def test_one_dispatch_one_transfer_per_step(served, drafted):
    """The whole draft/verify round rides the unified hot path: one jitted
    dispatch and one device->host pull per engine step (debug_guards also
    arms the transfer guard and the no-retrace check)."""
    spec, model, params = served
    eng = _engine(model, params, n_spec=3, draft=drafted)
    reqs = eng.serve([Request(prompt=list(p), max_new_tokens=8)
                      for p in _prompts(spec.vocab, 6, seed=3)])
    assert all(r.state == "done" for r in reqs)
    m = eng.metrics
    assert m.dispatches == m.steps > 0
    assert m.transfers_d2h == m.steps
    assert m.spec_rounds > 0


def test_stochastic_sampling_runs_clean(served, drafted):
    """Temperature > 0 slots ride the same fused round (device-side
    rejection sampling); debug_guards proves no stray transfer/retrace."""
    spec, model, params = served
    eng = _engine(model, params, n_spec=3, draft=drafted)
    reqs = eng.serve([
        Request(prompt=list(p), max_new_tokens=8,
                sampling=SamplingConfig(temperature=0.8 + 0.1 * i))
        for i, p in enumerate(_prompts(spec.vocab, 4, seed=4))])
    assert all(r.state == "done" for r in reqs)
    assert all(len(r.output) == 8 for r in reqs)
    assert eng.metrics.spec_proposed > 0


# ---------------------------------------------------------------------------
# identity under pool pressure (preemption recompute) and prefix CoW
# ---------------------------------------------------------------------------

def test_preemption_recompute_identity(served):
    """A page pool too small for all requests forces preempt + recompute
    mid-decode; the speculative engine must still match the non-spec
    engine token for token (draft pool lengths roll back with the slot)."""
    spec, model, params = served
    kw = dict(max_slots=3, max_seq=64, chunk_size=4, prefill_rows=1,
              page_size=8, n_pages=13)
    prompts = _prompts(spec.vocab, 3, seed=5, lo=6, hi=12)

    def run(n_spec, draft):
        eng = _engine(model, params, n_spec=n_spec, draft=draft, **kw)
        reqs = eng.serve([Request(prompt=list(p), max_new_tokens=40)
                          for p in prompts])
        assert all(r.state == "done" for r in reqs)
        return [r.output for r in reqs], eng.metrics

    base, _ = run(0, None)
    got, m = run(3, (model, params))
    assert got == base
    assert m.preemptions > 0  # the pool really was too small


def test_prefix_cache_cow_fork_identity(served):
    """A prefix-cache hit hands the speculative slot shared pages; the
    first divergent write CoW-forks the page in BOTH pools (target and
    draft mirror) through one fused copy.  Outputs must match the
    cache-off engine and each other."""
    spec, model, params = served
    kw = dict(max_slots=2, max_seq=64, chunk_size=8, prefill_rows=1,
              page_size=8, prefix_cache=True)
    prompt = list(range(16))

    eng = _engine(model, params, n_spec=3, draft=(model, params), **kw)
    [r1] = eng.serve([Request(prompt=list(prompt), max_new_tokens=10)])
    [r2] = eng.serve([Request(prompt=list(prompt), max_new_tokens=10)])
    assert r1.state == r2.state == "done"
    assert r1.output == r2.output
    assert r2.n_cached > 0  # the second request actually hit the cache

    off = _engine(model, params, n_spec=3, draft=(model, params),
                  **{**kw, "prefix_cache": False})
    [r3] = off.serve([Request(prompt=list(prompt), max_new_tokens=10)])
    assert r3.output == r1.output


# ---------------------------------------------------------------------------
# rejection sampler vs brute-force oracle
# ---------------------------------------------------------------------------

def _softmax(x):
    x = x - x.max()
    e = np.exp(x)
    return e / e.sum()


def _oracle(dec_logits, d_probs, d_toks, temps, widths, u_acc, u_fin):
    """Per-row Leviathan accept/reject, written as the obvious host loop."""
    b, k = d_toks.shape
    acc = np.zeros(b, np.int32)
    out = np.zeros((b, k + 1), np.int32)
    ne = np.zeros(b, np.int32)
    for r in range(b):
        w = int(widths[r])
        greedy = temps[r] <= 0.0
        tt = max(temps[r], 1e-4)
        p_t = np.stack([_softmax(dec_logits[r, i].astype(np.float64) / tt)
                        for i in range(k + 1)])
        a = 0
        for i in range(max(w - 1, 0)):
            t = int(d_toks[r, i])
            if greedy:
                ok = t == int(np.argmax(dec_logits[r, i]))
            else:
                ok = u_acc[r, i] < min(
                    1.0, p_t[i, t] / max(d_probs[r, i, t], 1e-20))
            if not ok:
                break
            a += 1
        full = a >= max(w - 1, 0)
        if greedy:
            final = int(np.argmax(dec_logits[r, a]))
        else:
            resid = p_t[a] - (0.0 if full else d_probs[r, min(a, k - 1)])
            resid = np.maximum(resid, 0.0)
            if resid.sum() <= 0.0:
                resid = p_t[a]
            cdf = np.cumsum(resid)
            final = int(np.argmax(cdf >= u_fin[r] * cdf[-1]))
        out[r, :k] = d_toks[r]
        out[r, a] = final
        acc[r] = a
        ne[r] = a + 1 if w > 0 else 0
    return acc, out, ne


def _random_case(rng, b=6, k=4, v=12):
    dec = rng.normal(size=(b, k + 1, v)).astype(np.float32) * 2.0
    dp = rng.dirichlet(np.ones(v), size=(b, k)).astype(np.float32)
    dt = np.stack([[rng.choice(v, p=dp[r, i] / dp[r, i].sum())
                    for i in range(k)] for r in range(b)]).astype(np.int32)
    temps = rng.choice([0.0, 0.7, 1.3], size=b).astype(np.float32)
    widths = rng.integers(0, k + 2, size=b).astype(np.int32)
    ua = rng.uniform(size=(b, k)).astype(np.float32)
    uf = rng.uniform(size=b).astype(np.float32)
    return dec, dp, dt, temps, widths, ua, uf


def _check_against_oracle(case):
    dec, dp, dt, temps, widths, ua, uf = case
    a, out, ne = jax.device_get(rejection_accept(
        jnp.asarray(dec), jnp.asarray(dp), jnp.asarray(dt),
        jnp.asarray(temps), jnp.asarray(widths), jnp.asarray(ua),
        jnp.asarray(uf)))
    oa, oout, one = _oracle(dec.astype(np.float64), dp.astype(np.float64),
                            dt, temps, widths, ua.astype(np.float64), uf)
    np.testing.assert_array_equal(a, oa)
    np.testing.assert_array_equal(ne, one)
    for r in range(len(oa)):
        if one[r]:  # only committed positions are contractual
            np.testing.assert_array_equal(out[r, :one[r]], oout[r, :one[r]])


def test_rejection_accept_matches_oracle_seeded():
    """200 random accept/reject interleavings (greedy and stochastic rows,
    clipped widths, inactive rows) against the brute-force oracle on
    SHARED uniforms — counts and every committed token must agree."""
    rng = np.random.default_rng(12345)
    for _ in range(200):
        _check_against_oracle(_random_case(rng))


def test_rejection_accept_greedy_is_argmax_chain():
    """Greedy rows emit exactly the target argmax chain: accepted drafts
    all equal the running argmax and the final token is the argmax at the
    rejection/bonus position — the algebra behind spec-on/spec-off token
    identity for ANY draft."""
    rng = np.random.default_rng(7)
    dec, dp, dt, _, widths, ua, uf = _random_case(rng, b=8, k=4, v=16)
    temps = np.zeros(8, np.float32)
    widths = np.full(8, 5, np.int32)
    a, out, ne = jax.device_get(rejection_accept(
        jnp.asarray(dec), jnp.asarray(dp), jnp.asarray(dt),
        jnp.asarray(temps), jnp.asarray(widths), jnp.asarray(ua),
        jnp.asarray(uf)))
    am = np.argmax(dec, -1)  # (B, K+1)
    for r in range(8):
        for i in range(int(ne[r])):
            assert out[r, i] == am[r, i]


def test_rejection_accept_distribution_is_target():
    """Monte-Carlo: with proposals drawn from the draft distribution, the
    first committed token's empirical law matches the target softmax
    (total-variation < 2%) even though the draft is very different —
    the Leviathan exactness guarantee, vectorized."""
    rng = np.random.default_rng(99)
    v, k, n = 10, 3, 40000
    dec_row = rng.normal(size=(k + 1, v)).astype(np.float32)
    dp_row = rng.dirichlet(np.ones(v) * 0.5, size=k).astype(np.float32)
    dec = np.broadcast_to(dec_row, (n, k + 1, v))
    dp = np.broadcast_to(dp_row, (n, k, v))
    dt = np.stack([rng.choice(v, size=n, p=dp_row[i] / dp_row[i].sum())
                   for i in range(k)], 1).astype(np.int32)
    temps = np.ones(n, np.float32)
    widths = np.full(n, k + 1, np.int32)
    ua = rng.uniform(size=(n, k)).astype(np.float32)
    uf = rng.uniform(size=n).astype(np.float32)
    _, out, ne = jax.device_get(rejection_accept(
        jnp.asarray(dec), jnp.asarray(dp), jnp.asarray(dt),
        jnp.asarray(temps), jnp.asarray(widths), jnp.asarray(ua),
        jnp.asarray(uf)))
    assert (ne >= 1).all()
    freq = np.bincount(out[:, 0], minlength=v) / n
    want = _softmax(dec_row[0].astype(np.float64))
    assert 0.5 * np.abs(freq - want).sum() < 0.02


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**31 - 1),
           b=st.integers(1, 5), k=st.integers(1, 5), v=st.integers(2, 9))
    @settings(max_examples=60, deadline=None)
    def test_rejection_accept_matches_oracle_hypothesis(seed, b, k, v):
        rng = np.random.default_rng(seed)
        _check_against_oracle(_random_case(rng, b=b, k=k, v=v))


# ---------------------------------------------------------------------------
# metrics, shims, refusals
# ---------------------------------------------------------------------------

def test_spec_metrics_counters(served):
    spec, model, params = served
    eng = _engine(model, params, n_spec=3, draft=(model, params),
                  max_seq=128)
    reqs = eng.serve([Request(prompt=list(p), max_new_tokens=9)
                      for p in _prompts(spec.vocab, 4, seed=6)])
    assert all(r.state == "done" for r in reqs)
    m = eng.metrics
    assert m.spec_proposed == 3 * m.spec_slot_rounds  # roomy max_seq: w=K+1
    assert m.spec_accepted == m.spec_proposed  # self-draft
    assert m.spec_emitted == 4 * m.spec_slot_rounds
    assert m.spec_rounds <= m.steps
    assert sum(a for a, _ in m.spec_by_slot.values()) == m.spec_accepted
    s = m.summary(reqs)
    assert s["spec_acceptance_rate"] == 1.0
    assert s["spec_tokens_per_round"] == 4.0
    assert "spec_by_slot" in s and s["spec_bonus"] == m.spec_slot_rounds
    # spec off: no speculative section in the summary
    off = _engine(model, params)
    offr = off.serve([Request(prompt=[1, 2, 3], max_new_tokens=3)])
    assert "spec_acceptance_rate" not in off.metrics.summary(offr)


def test_batched_sync_flag_is_deprecated(served):
    spec, model, params = served
    prompt = [5, 9, 2, 17, 33, 4]
    with pytest.warns(DeprecationWarning, match="batched_sync"):
        sd = SpeculativeDecoder(model, params, model, params, n_spec=3,
                                max_seq=64, temperature=1e-3,
                                batched_sync=False)
    out = sd.generate(prompt, 8)
    assert out == _greedy_reference(model, params, prompt, 8)


def test_engine_config_refusals(served, drafted):
    spec, model, params = served
    with pytest.raises(ValueError, match="unified"):
        ServeEngine(model, params,
                    EngineConfig(max_slots=2, max_seq=64, n_spec=2),
                    draft_model=model, draft_params=params)
    with pytest.raises(ValueError, match="draft"):
        ServeEngine(model, params,
                    EngineConfig(max_slots=2, max_seq=64, chunk_size=4,
                                 cache_layout="paged", page_size=8,
                                 unified=True, n_spec=2))
    with pytest.raises(ValueError, match="n_spec"):
        ServeEngine(model, params,
                    EngineConfig(max_slots=2, max_seq=64, chunk_size=4,
                                 cache_layout="paged", page_size=8,
                                 unified=True),
                    draft_model=model, draft_params=params)


def test_sharded_refuses_speculation(served):
    spec, model, params = served
    cfg = EngineConfig(max_slots=2, max_seq=64, chunk_size=4,
                       cache_layout="paged", page_size=8, unified=True,
                       n_spec=2, tp=2)
    with pytest.raises(ValueError, match="n_spec"):
        validate_engine_sharding(spec, cfg)
