"""Serving engine: continuous batching, chunked prefill, speculative
decoding, beam search."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.serving import EngineConfig, Request, ServeEngine
from repro.serving.beam import BeamSearcher
from repro.serving.sampling import SamplingConfig, sample
from repro.serving.speculative import SpeculativeDecoder

from conftest import tiny_dense_spec


@pytest.fixture(scope="module")
def served():
    spec = tiny_dense_spec()
    model = build_model(spec, mesh=None, param_dtype=jnp.float32,
                        compute_dtype=jnp.float32)
    params = model.init(jax.random.key(7))
    return spec, model, params


def _greedy_reference(model, params, prompt, n, max_seq=128):
    """Token-by-token greedy decode as ground truth."""
    cache = model.init_cache(1, max_seq)
    logits, cache = model.prefill(
        params, jnp.asarray([prompt], jnp.int32), cache=cache)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n - 1):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(logits[0])))
    return out


def test_engine_single_request_matches_reference(served):
    spec, model, params = served
    prompt = [5, 9, 2, 17, 33, 4, 8, 1]
    want = _greedy_reference(model, params, prompt, 8)
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=2, max_seq=64, chunk_size=4))
    [req] = eng.serve([Request(prompt=prompt, max_new_tokens=8)])
    assert req.state == "done"
    assert req.output == want


def test_engine_contin_batching_many_requests(served):
    spec, model, params = served
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, spec.vocab, size=rng.integers(3, 12)))
               for _ in range(6)]
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=3, max_seq=64, chunk_size=4))
    reqs = eng.serve([Request(prompt=[int(t) for t in p], max_new_tokens=5)
                      for p in prompts])
    assert all(r.state == "done" for r in reqs)
    for p, r in zip(prompts, reqs):
        want = _greedy_reference(model, params, [int(t) for t in p], 5)
        assert r.output == want, "continuous batching changed outputs"


def test_engine_chunked_prefill_bounds_queue(served):
    spec, model, params = served
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=2, max_seq=128, chunk_size=8))
    long_prompt = list(range(1, 50))
    short = Request(prompt=[3, 1, 4], max_new_tokens=3)
    eng.submit(Request(prompt=long_prompt, max_new_tokens=3))
    eng.submit(short)
    eng.run()
    assert short.state == "done"


def test_sampling_modes():
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    assert int(sample(logits, jax.random.key(0), SamplingConfig())[0]) == 1
    tok = sample(logits, jax.random.key(0),
                 SamplingConfig(temperature=1.0, top_k=2))
    assert int(tok[0]) in (1, 2)
    tok = sample(logits, jax.random.key(0),
                 SamplingConfig(temperature=0.5, top_p=0.6))
    assert int(tok[0]) == 1


def test_speculative_decoder_exactness_with_self_draft(served):
    """With draft == target and temperature ~ greedy, every token must be
    accepted and match greedy decoding."""
    spec, model, params = served
    sd = SpeculativeDecoder(model, params, model, params, n_spec=3,
                            max_seq=96, temperature=1e-3)
    prompt = [5, 9, 2, 17]
    out = sd.generate(prompt, 10)
    want = _greedy_reference(model, params, prompt, 10)
    assert out == want
    assert sd.stats.acceptance_rate > 0.95
    assert sd.stats.tokens_per_pass > 2.0


def test_speculative_decoder_different_draft(served):
    spec, model, params = served
    draft_model = build_model(spec, mesh=None, param_dtype=jnp.float32,
                              compute_dtype=jnp.float32)
    draft_params = draft_model.init(jax.random.key(99))  # different weights
    sd = SpeculativeDecoder(model, params, draft_model, draft_params,
                            n_spec=4, max_seq=96, temperature=1e-3)
    out = sd.generate([5, 9, 2, 17], 10)
    want = _greedy_reference(model, params, [5, 9, 2, 17], 10)
    # rejection sampling at ~greedy temperature preserves target outputs
    assert out == want
    assert sd.stats.acceptance_rate < 1.0  # bad draft gets rejected


def test_beam_search_beats_greedy_logprob(served):
    spec, model, params = served
    bs = BeamSearcher(model, params, beam_size=4, max_seq=64,
                      length_penalty=0.0)
    prompt = [5, 9, 2, 17]
    toks, score = bs.search(prompt, 6)
    assert len(toks) == 6

    def seq_logprob(tokens):
        cache = model.init_cache(1, 64)
        logits, cache = model.prefill(params, jnp.asarray([prompt]),
                                      cache=cache)
        total = 0.0
        for tok in tokens:
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            total += float(lp[0, tok])
            logits, cache = model.decode_step(
                params, cache, jnp.asarray([[tok]], jnp.int32))
        return total

    greedy = _greedy_reference(model, params, prompt, 6)
    assert seq_logprob(toks) >= seq_logprob(greedy) - 1e-4
    assert score == pytest.approx(seq_logprob(toks), abs=2e-3)
