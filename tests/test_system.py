"""End-to-end behaviour tests for the paper's system: the analytical model
(GenZ) cross-validated against the executable framework's compiled HLO, and
whole-path integration checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GenZ, Optimizations, ParallelismConfig, Workload,
                        paper_model)
from repro.core.profiler import PassSpec, model_ops, pass_flops
from repro.configs import registry
from repro.configs.shapes import SHAPES, applicable
from repro.launch import hlo_cost
from repro.models import build_model


def test_analytical_flops_match_compiled_hlo_dense():
    """Our stand-in for the paper's real-hardware validation (§III-D): the
    GenZ operator model's FLOPs must match the compiled HLO of the real JAX
    model within a few percent (geomean over archs), single device."""
    errs = []
    for arch in ["qwen1.5-0.5b", "deepseek-7b", "yi-34b", "rwkv6-3b"]:
        spec = registry.get_reduced(arch)
        model = build_model(spec, mesh=None, param_dtype=jnp.float32,
                            compute_dtype=jnp.float32, attn_impl="direct")
        B, S = 2, 32
        params = jax.eval_shape(model.init,
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
        compiled = jax.jit(
            lambda p, t: model.forward(p, t)).lower(params, toks).compile()
        measured = hlo_cost.analyze(compiled.as_text()).flops

        ops = model_ops(spec, PassSpec(B, S, S, True), ParallelismConfig(),
                        Optimizations(act_dtype="fp32", weight_dtype="fp32"))
        predicted = pass_flops(ops)
        rel = abs(measured - predicted) / measured
        errs.append(rel)
    geomean = float(np.exp(np.mean(np.log(np.maximum(errs, 1e-4)))))
    # paper reports 5.82% geomean against real hardware; we hold our
    # analytical model to a comparable bar against compiled HLO
    assert geomean < 0.20, (errs, geomean)


def test_dryrun_artifacts_complete_and_clean():
    """Every applicable (arch x shape) cell must have compiled on BOTH
    production meshes (the multi-pod dry-run deliverable)."""
    import json
    from pathlib import Path
    art = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"
    if not art.exists():
        pytest.skip("dry-run artifacts not generated yet")
    for mesh in ("pod16x16", "pod2x16x16"):
        mdir = art / mesh
        if not mdir.exists():
            pytest.skip(f"{mesh} sweep not run yet")
        for arch in registry.ARCH_IDS:
            spec = registry.get_spec(arch)
            for name, shape in SHAPES.items():
                f = mdir / f"{arch}__{name}.json"
                ok, why = applicable(spec, shape)
                if not f.exists():
                    pytest.skip(f"{mesh} sweep incomplete ({f.name})")
                rec = json.loads(f.read_text())
                if ok:
                    assert rec["status"] == "ok", (mesh, arch, name,
                                                   rec.get("error"))
                    assert rec["hlo_cost"]["flops"] > 0
                else:
                    assert rec["status"] == "skipped"


def test_genz_facade_end_to_end():
    g = GenZ.tpu_v5e_pod(16, 16)
    rep = g.estimate("yi-34b", workload=Workload(batch=16, tau_p=4096,
                                                 tau_d=512),
                     batch=16, parallelism=dict(tp=16, dp=16))
    assert rep.ttft > 0 and rep.tpot > 0
    assert rep.decode.memory.fits


def test_full_request_path_tiny_model():
    """Train a few steps, checkpoint, serve the trained model — the whole
    lifecycle on one CPU."""
    from repro.data.pipeline import DataConfig
    from repro.serving import EngineConfig, Request, ServeEngine
    from repro.training.train_loop import TrainConfig, Trainer
    import tempfile

    spec = registry.get_reduced("qwen1.5-0.5b").scaled(vocab=64)
    model = build_model(spec, mesh=None, param_dtype=jnp.float32,
                        compute_dtype=jnp.float32)
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(model, DataConfig(vocab=64, seq_len=32, global_batch=8),
                     TrainConfig(checkpoint_dir=d, checkpoint_every=10),
                     rng=jax.random.key(0))
        tr.run(0, 10)
        eng = ServeEngine(model, tr.params,
                          EngineConfig(max_slots=2, max_seq=64,
                                       chunk_size=8))
        [req] = eng.serve([Request(prompt=[1, 2, 3, 4], max_new_tokens=6)])
        assert req.state == "done" and len(req.output) == 6
