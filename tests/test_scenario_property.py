"""Property-based tests (hypothesis) for the Scenario sweep layer."""

import math

import pytest

pytest.importorskip("hypothesis", reason="dev extra; see requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import Workload  # noqa: E402
from repro.scenario import (ChunkedSpec, Scenario, SpeculativeSpec, Sweep,  # noqa: E402
                            feasible)

SETTINGS = dict(max_examples=25, deadline=None)

MODELS = ["llama3-8b", "llama3-70b", "mixtral-8x7b"]


def _base():
    return Scenario.make("llama3-8b", use_case="chat", batch=1,
                         platform="hgx-h100x8")


@given(n_models=st.integers(1, 3), tps=st.lists(
    st.sampled_from([1, 2, 4, 8, 16]), min_size=1, max_size=5, unique=True),
    batches=st.lists(st.integers(1, 64), min_size=1, max_size=3,
                     unique=True))
@settings(**SETTINGS)
def test_unpruned_grid_size_is_axis_product(n_models, tps, batches):
    grid = Sweep(_base()).over(model=MODELS[:n_models], tp=tps,
                               batch=batches)
    scs = grid.scenarios(prune=False)
    assert len(scs) == n_models * len(tps) * len(batches)
    assert grid.size_unpruned == len(scs)
    # every grid point is distinct
    assert len(set(scs)) == len(scs)


@given(tps=st.lists(st.sampled_from([1, 2, 4, 8, 16, 32, 64]), min_size=1,
                    max_size=7, unique=True))
@settings(**SETTINGS)
def test_pruning_partitions_the_grid(tps):
    grid = Sweep(_base()).over(tp=tps)
    kept, dropped = grid.partition()
    assert len(kept) + len(dropped) == len(tps)
    assert kept == grid.scenarios()
    # hgx-h100x8: exactly the tp degrees that fit 8 NPUs survive
    assert sorted(s.parallelism.tp for s in kept) == sorted(
        t for t in tps if t <= 8)
    assert all(feasible(s) for s in kept)
    assert not any(feasible(s) for s in dropped)


@given(batch=st.integers(1, 512), tau_p=st.integers(1, 100_000),
       tau_d=st.integers(1, 10_000), beam=st.integers(1, 8),
       tp=st.sampled_from([1, 2, 4, 8]),
       mode=st.sampled_from(["monolithic", "chunked", "speculative",
                             "disaggregated"]))
@settings(**SETTINGS)
def test_json_roundtrip_property(batch, tau_p, tau_d, beam, tp, mode):
    kw = {}
    if mode == "chunked":
        kw["chunked"] = ChunkedSpec(chunk=max(batch, 2), decode_batch=batch)
    if mode == "speculative":
        kw["speculative"] = SpeculativeSpec(draft="llama3-8b", n=4,
                                            gamma=0.5)
    sc = Scenario.make("llama3-70b",
                       workload=Workload(batch=batch, tau_p=tau_p,
                                         tau_d=tau_d, beam=beam),
                       batch=batch, parallelism=dict(tp=tp), mode=mode, **kw)
    back = Scenario.from_json(sc.to_json())
    assert back == sc
    assert back.workload.tau_p == tau_p
    assert back.parallelism.tp == tp


@given(tau_p=st.integers(64, 32_768), batch=st.integers(1, 32))
@settings(max_examples=10, deadline=None)
def test_analytical_metrics_positive_and_consistent(tau_p, batch):
    from repro.scenario import run
    sc = Scenario.make("llama3-8b",
                       workload=Workload(batch=batch, tau_p=tau_p,
                                         tau_d=128),
                       batch=batch, parallelism=dict(tp=8),
                       opt=dict(weight_dtype="fp8", act_dtype="fp8",
                                kv_dtype="fp8"))
    rep, = run([sc], max_workers=1)
    assert rep.status in ("ok", "oom")
    assert rep.ttft_s > 0 and rep.tpot_s > 0
    assert math.isclose(rep.latency_s, rep.ttft_s + rep.tpot_s * 128,
                        rel_tol=1e-9)
    assert rep.energy_per_token_j > 0
