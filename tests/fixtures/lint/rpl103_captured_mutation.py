"""Seeded violation: jitted code mutating captured state (the write
happens once, at trace time, then silently never again)."""
import jax

HISTORY = []


def accumulate(x):
    global total  # EXPECT: RPL103
    total = x
    HISTORY.append(x)  # EXPECT: RPL103
    return x + 1


accumulate_jit = jax.jit(accumulate)
