"""Seeded violation: reading a buffer after donating it to a jit call."""
import jax


def apply_update(params, grads):
    return jax.tree_util.tree_map(lambda p, g: p - g, params, grads)


update_jit = jax.jit(apply_update, donate_argnums=(0,))


def train_step(params, grads):
    new_params = update_jit(params, grads)
    stale = params  # EXPECT: RPL401
    return new_params, stale


def train_step_ok(params, grads):
    norm = jax.tree_util.tree_reduce(
        lambda a, b: a + b.sum(), params, 0.0)  # read BEFORE the donate
    params = update_jit(params, grads)  # rebinding revives the name
    return params, norm
