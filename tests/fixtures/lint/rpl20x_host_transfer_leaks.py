"""Seeded violations: implicit device->host syncs on the serving hot
path.  The class is named ``ServeEngine`` so the reachability walk seeds
from ``step`` exactly as it does for the real engine."""
import jax
import jax.numpy as jnp
import numpy as np


class ServeEngine:
    def __init__(self):
        self.probs = jnp.zeros((4, 8))
        self.table = [0] * 16

    def step(self):
        return self._pick(self.probs)

    def _pick(self, probs):
        best = probs.argmax(-1)
        a = best.item()  # EXPECT: RPL201
        b = int(best[0])  # EXPECT: RPL202
        host = np.asarray(probs)  # EXPECT: RPL203
        d = self.table[best[1]]  # EXPECT: RPL204
        for tok in best:  # EXPECT: RPL204
            d += int(tok)  # EXPECT: RPL202
        pulled = jax.device_get(best)  # sanctioned: explicit, batched
        return a + b + d + int(pulled[0]) + float(host.sum())

    def offline_report(self, probs):
        # NOT reachable from an entry point: syncs here are fine
        return probs.argmax(-1).item()
