"""Seeded violation: computed static_argnums (retrace-per-call trap)."""
import jax

STATICS = (1, 2)


def f(x, n, m):
    return x * n + m


f_bad = jax.jit(f, static_argnums=STATICS)  # EXPECT: RPL102
g_bad = jax.jit(f, static_argnames=[s for s in ("n", "m")])  # EXPECT: RPL102
f_ok = jax.jit(f, static_argnums=(1, 2))
g_ok = jax.jit(f, static_argnames=("n", "m"))
