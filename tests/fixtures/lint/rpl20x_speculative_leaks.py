"""Seeded violations: implicit device->host syncs inside the batched
draft/verify hot path.  The class is named ``PackedSpeculator`` so the
reachability walk seeds from ``dispatch`` / ``fork_page`` exactly as it
does for the real speculator — whose fused round must issue ZERO syncs
(the engine performs the step's single explicit ``device_get`` on what
``dispatch`` returns; any implicit pull here would add a second
device->host transfer per step and break the one-transfer invariant)."""
import jax
import numpy as np


class PackedSpeculator:
    def __init__(self):
        self.d_lens = [0] * 8

    def dispatch(self, cache, sampled, logits):
        emitted = int(sampled[0])  # EXPECT: RPL202
        host_toks = np.asarray(logits)  # EXPECT: RPL203
        self.d_lens[sampled[1]] = emitted  # EXPECT: RPL204
        for tok in sampled:  # EXPECT: RPL204
            emitted += tok.item()  # EXPECT: RPL201
        pulled = jax.device_get((sampled, logits))  # sanctioned: explicit
        return cache, (emitted + int(host_toks[0]), pulled)

    def fork_page(self, cache, kv):
        return cache, kv.item()  # EXPECT: RPL201

    def acceptance_report(self, logits):
        # NOT reachable from an entry point: syncs here are fine
        return float(logits.sum())
