"""Seeded violations: implicit device->host syncs inside the prefix-cache
entry points.  The class is named ``PrefixCache`` so the reachability walk
seeds from ``lookup`` / ``insert`` exactly as it does for the real radix
tree (which must stay pure host bookkeeping — any device sync in the
lookup path serializes every admission against the device stream)."""
import numpy as np


class PrefixCache:
    def __init__(self):
        self.page_of = [0] * 16

    def lookup(self, tokens_dev):
        n = int(tokens_dev[0])  # EXPECT: RPL202
        head = self.page_of[tokens_dev[1]]  # EXPECT: RPL204
        return n + head

    def insert(self, tokens_dev):
        return self._register(tokens_dev)

    def _register(self, tokens_dev):
        host = np.asarray(tokens_dev)  # EXPECT: RPL203
        total = tokens_dev.sum().item()  # EXPECT: RPL201
        for t in tokens_dev:  # EXPECT: RPL204
            total += int(t)  # EXPECT: RPL202
        return total + int(host[0])

    def audit(self, tokens_dev):
        # NOT reachable from an entry point: syncs here are fine
        return tokens_dev.sum().item()
