"""Seeded violation: branching on a tracer inside a jitted function.

Lines carrying a ``# EXPECT: RPLxxx`` marker are the golden findings the
corpus test asserts repro-lint reports (and nothing else).
"""
import jax
import jax.numpy as jnp


def scale(x):
    if x.sum() > 0:  # EXPECT: RPL101
        return x * 2.0
    while x.max() > 1.0:  # EXPECT: RPL101
        x = x * 0.5
    flip = -x if x.mean() < 0 else x  # EXPECT: RPL101
    for row in x:  # EXPECT: RPL101
        flip = flip + row
    return flip


scale_jit = jax.jit(scale)


def safe(x):
    # static facts do not taint: shapes, dtypes and len() are fine
    if x.shape[0] > 4:
        return jnp.zeros_like(x)
    return x


safe_jit = jax.jit(safe)
