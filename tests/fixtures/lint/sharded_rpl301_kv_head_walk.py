"""Seeded RPL301: a shard_map worker walking the *global* kv-head axis.

Under tensor parallelism every worker's KV pool is the per-shard slice —
``LOCAL_HKV = GLOBAL_HKV // TP`` heads.  The bug seeded here is the one
the concrete kernel-bounds pass exists to catch and AST linting cannot:
the grid and the BlockSpec index map still walk ``GLOBAL_HKV``, so every
block they select is in bounds at tp=1 and escapes the pool's head axis
on every shard of a tp>=2 mesh.  The ``# EXPECT`` marker sits on the
``pallas_call`` line, where the pass reports it.

This file is exercised by building a ``KernelCase`` around
``local_shard_case`` and running ``check_kernel_bounds`` on it (see
tests/test_tp_serving.py); it deliberately does NOT match the
``rpl*.py`` fixture glob, because the AST-only golden sweep cannot see
value-dependent bounds.
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

GLOBAL_HKV = 4
TP = 2
LOCAL_HKV = GLOBAL_HKV // TP
PAGES, PAGE, D = 6, 8, 16


def _copy_kernel(pt_ref, kv_ref, o_ref):
    o_ref[...] = kv_ref[...]


def sharded_page_gather(kv_pool, page_table):
    """Gather the first page of every slot, per kv head.

    ``kv_pool`` is the worker's local slice ``(PAGES, LOCAL_HKV, PAGE,
    D)`` but the grid's head axis and both index maps run to
    ``GLOBAL_HKV`` — heads ``h >= LOCAL_HKV`` select blocks past the
    pool's head axis at every grid point of a sharded run.
    """
    slots = page_table.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(slots, GLOBAL_HKV),
        in_specs=[pl.BlockSpec((1, 1, PAGE, D),
                               lambda b, h, pt: (pt[b, 0], h, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, PAGE, D),
                               lambda b, h, pt: (b, h, 0, 0)),
    )
    return pl.pallas_call(  # EXPECT: RPL301
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((slots, GLOBAL_HKV, PAGE, D),
                                       jnp.float32),
        interpret=True,
    )(page_table, kv_pool)


def local_shard_case():
    """The thunk ``check_kernel_bounds`` runs: per-shard pool, global
    head walk."""
    kv_pool = np.zeros((PAGES, LOCAL_HKV, PAGE, D), np.float32)
    page_table = np.asarray([[1, 2, 0], [3, 4, 5]], np.int32)
    return sharded_page_gather(kv_pool, page_table)
