"""Seeded violations: implicit device->host syncs inside the P/D
migration hot path.  The classes are named ``DisaggCluster`` /
``KvMigrationChannel`` so the reachability walk seeds from ``step`` /
``pump`` / ``_copy_pages`` exactly as it does for the real cluster
(whose migration scheduling must stay pure host bookkeeping — a sync
between the pump and the engine steps stalls *both* pools)."""
import numpy as np


class KvMigrationChannel:
    def __init__(self):
        self.page_of = [0] * 16

    def pump(self, tokens_dev):
        n = int(tokens_dev[0])  # EXPECT: RPL202
        head = self.page_of[tokens_dev[1]]  # EXPECT: RPL204
        return n + head

    def stats(self, tokens_dev):
        # NOT reachable from an entry point: syncs here are fine
        return tokens_dev.sum().item()


class DisaggCluster:
    def step(self, logits):
        return self._route(logits)

    def _route(self, logits):
        host = np.asarray(logits)  # EXPECT: RPL203
        total = logits.sum().item()  # EXPECT: RPL201
        for t in logits:  # EXPECT: RPL204
            total += int(t)  # EXPECT: RPL202
        return total + int(host[0])

    def _copy_pages(self, sampled):
        return float(sampled)  # EXPECT: RPL202
