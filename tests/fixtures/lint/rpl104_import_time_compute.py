"""Seeded violation: device compute at module import time."""
import jax
import jax.numpy as jnp

NORM = jnp.ones((8,)) / 8.0  # EXPECT: RPL104
KEY = jax.random.key(0)  # EXPECT: RPL104

# registration-style calls are allowed at import time
jax.tree_util.register_pytree_node(dict, lambda d: (
    tuple(d.values()), tuple(d)), lambda k, v: dict(zip(k, v)))
