"""Multi-device correctness, run in subprocesses with fake devices (the
main test process must keep seeing 1 CPU device)."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def run_with_devices(n: int, code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_forward_matches_single_device():
    """TP+DP sharded forward == unsharded forward (same params)."""
    run_with_devices(8, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.models import build_model
        from repro.configs import registry

        spec = registry.get_reduced("minitron-8b")
        mesh = make_mesh((2, 4), ("data", "model"))
        m1 = build_model(spec, mesh=None, param_dtype=jnp.float32,
                         compute_dtype=jnp.float32)
        params = m1.init(jax.random.key(0))
        tokens = jax.random.randint(jax.random.key(1), (4, 16), 0, spec.vocab)
        want = m1.forward(params, tokens)

        m2 = build_model(spec, mesh=mesh, policy="inference_tp",
                         param_dtype=jnp.float32, compute_dtype=jnp.float32)
        with mesh:
            got = jax.jit(lambda p, t: m2.forward(p, t))(params, tokens)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-3, rtol=1e-3)
        print("OK")
    """)


def test_moe_shardmap_matches_dense_oracle():
    """Expert-parallel all-to-all MoE == dense no-drop oracle when capacity
    is ample."""
    run_with_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.models import build_model
        from repro.models.moe import moe_block
        from repro.models.common import ModelContext
        from repro.configs import registry
        from repro.sharding import get_policy

        spec = registry.get_reduced("deepseek-moe-16b")
        mesh = make_mesh((1, 4), ("data", "model"))
        model = build_model(spec, mesh=mesh, param_dtype=jnp.float32,
                            compute_dtype=jnp.float32,
                            moe_capacity_factor=8.0)
        params = model.init(jax.random.key(0))
        moe_params = params["layers"]["pos0"]["ffn"]
        moe_params = jax.tree.map(lambda x: x[0], moe_params)  # layer 0
        x = jax.random.normal(jax.random.key(2), (4, 8, spec.d_model))

        ctx_d = model.ctx.with_(moe_impl="dense", mesh=None)
        want = moe_block(spec, ctx_d, moe_params, x)
        ctx_s = model.ctx.with_(moe_impl="shardmap")
        with mesh:
            got = jax.jit(lambda p, x: moe_block(spec, ctx_s, p, x))(
                moe_params, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-3, rtol=2e-3)
        print("OK")
    """)


def test_train_step_sharded_loss_matches():
    run_with_devices(8, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.models import build_model
        from repro.configs import registry

        spec = registry.get_reduced("qwen1.5-0.5b")
        tokens = jax.random.randint(jax.random.key(1), (8, 32), 0, spec.vocab)
        targets = jax.random.randint(jax.random.key(2), (8, 32), 0, spec.vocab)

        m1 = build_model(spec, mesh=None, param_dtype=jnp.float32,
                         compute_dtype=jnp.float32)
        params = m1.init(jax.random.key(0))
        l1 = float(m1.loss(params, tokens, targets))
        g1 = jax.grad(lambda p: m1.loss(p, tokens, targets))(params)

        mesh = make_mesh((4, 2), ("data", "model"))
        m2 = build_model(spec, mesh=mesh, policy="train_2d",
                         param_dtype=jnp.float32, compute_dtype=jnp.float32)
        with mesh:
            l2 = float(jax.jit(lambda p: m2.loss(p, tokens, targets))(params))
            g2 = jax.jit(jax.grad(lambda p: m2.loss(p, tokens, targets)))(
                params)
        assert abs(l1 - l2) < 2e-3, (l1, l2)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-3, rtol=5e-3)
        print("OK")
    """)


def test_pipeline_parallel_forward():
    """GPipe over a 4-stage axis == sequential layer application."""
    run_with_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.training.pipeline import (PipelineConfig, bubble_fraction,
                                             make_pipelined_fn)

        mesh = make_mesh((4,), ("pod",))
        L, D = 8, 32
        ws = jax.random.normal(jax.random.key(0), (L, D, D)) * 0.1

        def stage_fn(w_stack, x):
            def body(h, w):
                return jnp.tanh(h @ w), None
            out, _ = jax.lax.scan(body, x, w_stack)
            return out

        n_micro, mb, S = 4, 2, 4
        x = jax.random.normal(jax.random.key(1), (n_micro, mb, S, D))

        # reference: all layers sequentially on each microbatch
        want = jax.vmap(lambda xm: stage_fn(ws, xm))(x)

        fn = make_pipelined_fn(stage_fn, mesh, 4, ws,
                               PipelineConfig(n_micro=n_micro))
        with mesh:
            got = jax.jit(fn)(ws, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
        assert abs(bubble_fraction(4, 4) - 3/7) < 1e-9
        print("OK")
    """)


def test_dryrun_cell_small_mesh():
    """The dry-run machinery end-to-end on a small fake fleet, including
    hlo_cost extraction."""
    out = run_with_devices(16, """
        import jax, jax.numpy as jnp, json
        from dataclasses import replace
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import bundle_for
        from repro.launch import hlo_cost
        from repro.configs.shapes import SHAPES

        mesh = make_mesh((4, 4), ("data", "model"))
        shape = replace(SHAPES["decode_32k"], global_batch=8, seq_len=512)
        b = bundle_for("granite-moe-3b-a800m", shape, mesh,
                       param_dtype=jnp.float32, compute_dtype=jnp.float32)
        with mesh:
            compiled = b.lower().compile()
        rec = hlo_cost.analyze_compiled(compiled, byte_scale=0.5)
        hc = rec["hlo_cost"]
        assert hc["flops"] > 0 and hc["bytes"] > 0
        assert hc["total_collective_bytes"] > 0  # EP all-to-alls at least
        assert "all-to-all" in hc["collective_bytes"]
        print(json.dumps({"flops": hc["flops"]}))
    """)
    assert "flops" in out


def test_hlo_cost_scan_trip_multiplication():
    run_with_devices(1, """
        import jax, jax.numpy as jnp
        from repro.launch import hlo_cost

        D, L = 256, 8
        w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
        x = jax.ShapeDtypeStruct((32, D), jnp.float32)

        def one(params, x):
            return x @ params[0]

        def scanned(params, x):
            return jax.lax.scan(lambda h, w: (h @ w, None), x, params)[0]

        c1 = hlo_cost.analyze(jax.jit(one).lower(w, x).compile().as_text())
        cL = hlo_cost.analyze(
            jax.jit(scanned).lower(w, x).compile().as_text())
        expect1 = 2 * 32 * D * D
        assert abs(c1.flops - expect1) / expect1 < 0.05, c1.flops
        assert abs(cL.flops - L * expect1) / (L * expect1) < 0.05, cL.flops
        # XLA's own analysis does NOT multiply: ours must exceed it
        ca = jax.jit(scanned).lower(w, x).compile().cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        assert cL.flops > 4 * float(ca["flops"])
        print("OK")
    """)


def test_elastic_checkpoint_resharding(tmp_path):
    """A checkpoint written under one mesh restores onto a different mesh
    (elastic shrink): arrays are stored unsharded and re-placed against the
    new shardings."""
    run_with_devices(8, f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.models import build_model
        from repro.configs import registry
        from repro.training.checkpoint import CheckpointManager

        spec = registry.get_reduced("qwen1.5-0.5b")
        mesh8 = make_mesh((4, 2), ("data", "model"))
        m8 = build_model(spec, mesh=mesh8, policy="train_2d",
                         param_dtype=jnp.float32, compute_dtype=jnp.float32)
        params = m8.init(jax.random.key(0))
        sh8 = m8.param_shardings(mesh8)
        params = jax.device_put(params, jax.tree.map(
            lambda s, p: s, sh8, params))
        mgr = CheckpointManager(r"{tmp_path}")
        mgr.save(1, params)

        # 'surviving fleet': 4 devices, different axis split
        mesh4 = make_mesh((2, 2), ("data", "model"))
        devs = np.array(jax.devices()[:4]).reshape(2, 2)
        from jax.sharding import Mesh
        mesh4 = Mesh(devs, ("data", "model"))
        m4 = build_model(spec, mesh=mesh4, policy="train_2d",
                         param_dtype=jnp.float32, compute_dtype=jnp.float32)
        sh4 = m4.param_shardings(mesh4)
        out = mgr.restore(jax.eval_shape(lambda: params), shardings=sh4)
        assert out is not None
        got, _, step = out
        assert step == 1
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored leaves actually live on the 4-device mesh
        leaf = jax.tree.leaves(got)[1]
        assert set(leaf.sharding.mesh.devices.flat) <= set(jax.devices()[:4])
        print("OK")
    """)


def test_hlo_cost_collective_accounting():
    run_with_devices(8, """
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import make_mesh
        from repro.launch import hlo_cost

        mesh = make_mesh((8,), ("model",))
        D = 512
        w = jax.ShapeDtypeStruct((D, D), jnp.float32)
        x = jax.ShapeDtypeStruct((64, D), jnp.float32)

        def f(w, x):
            y = x @ w
            return jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, P(None, None)))

        with mesh:
            c = jax.jit(f, in_shardings=(
                NamedSharding(mesh, P("model", None)),
                NamedSharding(mesh, P(None, "model")))).lower(w, x).compile()
        cost = hlo_cost.analyze(c.as_text())
        # contraction over the sharded dim -> all-reduce of (64, 512) f32
        ar = cost.coll_bytes.get("all-reduce", 0)
        expect = 2 * (7/8) * 64 * D * 4
        assert abs(ar - expect) / expect < 0.3, (ar, expect)
        print("OK")
    """)
