"""Property tests for the unified token-packed step's ragged kernel:
random mixes of decode slots and prefill chunk widths, packed exactly the
way the engine packs them, must match the gather reference in fp32 —
including empty-prefill and decode-only packings, partial last pages,
inactive segments and null-page padding.

(The kernel combines pages with an online softmax, so the last ~2 ULP of
fp32 differ from the oracle's single full-width softmax; the comparison
is pinned at 2e-6 absolute/relative, far below any bf16 ULP.)

The hypothesis half is skipped when hypothesis isn't installed (see
requirements-dev.txt); the seeded sweep below always runs.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops as kops

HQ, HKV, D, PS, MP = 4, 2, 16, 4, 8
TOL = dict(atol=2e-6, rtol=2e-6)


def _build_packing(rng, segs, max_q):
    """segs: list of (q_len, kv_len).  Returns the kernel's argument
    tuple, packing segments back-to-back with fresh pages per segment."""
    s_count = max(len(segs), 1)
    n_pages = 1 + sum(-(-kv // PS) for _, kv in segs) + 1
    kp = jnp.asarray(rng.normal(size=(n_pages, HKV, PS, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, HKV, PS, D)), jnp.float32)
    pt = np.zeros((s_count, MP), np.int32)
    nxt = 1
    q_start, q_len, kv_len = [], [], []
    off = 0
    for ql, kl in segs:
        q_start.append(off)
        q_len.append(ql)
        kv_len.append(kl)
        for i in range(-(-kl // PS)):
            pt[len(q_start) - 1, i] = nxt
            nxt += 1
        off += ql
    t = max(off, 1)
    q = jnp.asarray(rng.normal(size=(t, HQ, D)), jnp.float32)
    return (q, kp, vp, jnp.asarray(pt),
            jnp.asarray(q_start or [0], jnp.int32),
            jnp.asarray(q_len or [0], jnp.int32),
            jnp.asarray(kv_len or [0], jnp.int32))


def _valid_rows(q_start, q_len, t):
    valid = np.zeros((t,), bool)
    for s, l in zip(np.asarray(q_start), np.asarray(q_len)):
        valid[s:s + l] = True
    return valid


def _assert_kernel_matches_oracle(segs, max_q):
    rng = np.random.default_rng(abs(hash(tuple(segs))) % (2 ** 31))
    args = _build_packing(rng, segs, max_q)
    want = kops.ragged_paged_attention(*args, max_q=max_q, impl="gather")
    got = kops.ragged_paged_attention(*args, max_q=max_q, impl="pallas",
                                      interpret=True)
    valid = _valid_rows(args[4], args[5], args[0].shape[0])
    np.testing.assert_allclose(np.asarray(got, np.float32)[valid],
                               np.asarray(want, np.float32)[valid], **TOL)


def _random_segs(rng, n_decode, n_prefill, max_q):
    """The engine's packing shape: decode segments first (q_len <= 1),
    prefill chunk segments after (q_len <= max_q), interleaved with
    inactive segments, kv capped by the page-table row."""
    segs = []
    for _ in range(n_decode):
        if rng.integers(0, 4) == 0:
            segs.append((0, 0))  # idle slot
        else:
            segs.append((1, int(rng.integers(1, MP * PS))))
    for _ in range(n_prefill):
        if rng.integers(0, 4) == 0:
            segs.append((0, 0))  # idle prefill row
        else:
            w = int(rng.integers(1, max_q + 1))
            lo = int(rng.integers(0, MP * PS - w))
            segs.append((w, lo + w))
    return segs


# -- always-on seeded sweep ---------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_random_mixed_packings_seeded(seed):
    rng = np.random.default_rng(seed)
    max_q = int(rng.integers(2, 9))
    segs = _random_segs(rng, n_decode=int(rng.integers(1, 5)),
                        n_prefill=int(rng.integers(0, 3)), max_q=max_q)
    _assert_kernel_matches_oracle(segs, max_q)


def test_decode_only_packing():
    _assert_kernel_matches_oracle([(1, 5), (1, 16), (1, 1), (1, 31)],
                                  max_q=4)


def test_empty_prefill_packing():
    """All prefill rows idle: only the decode segments contribute."""
    _assert_kernel_matches_oracle([(1, 9), (1, 2), (0, 0), (0, 0)],
                                  max_q=6)


def test_everything_inactive():
    """A fully idle packing must simply not crash (outputs are garbage
    rows nobody reads)."""
    rng = np.random.default_rng(0)
    args = _build_packing(rng, [(0, 0), (0, 0)], 4)
    out = kops.ragged_paged_attention(*args, max_q=4, impl="pallas",
                                      interpret=True)
    assert out.shape == args[0].shape


# -- hypothesis half ----------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # requirements-dev extra; the seeded sweep still runs
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def packings(draw):
        max_q = draw(st.integers(2, 8))
        n_decode = draw(st.integers(0, 4))
        n_prefill = draw(st.integers(0, 3))
        segs = []
        for _ in range(n_decode):
            active = draw(st.booleans())
            kv = draw(st.integers(1, MP * PS))
            segs.append((1, kv) if active else (0, 0))
        for _ in range(n_prefill):
            active = draw(st.booleans())
            w = draw(st.integers(1, max_q))
            lo = draw(st.integers(0, MP * PS - w - 1))
            segs.append((w, lo + w) if active else (0, 0))
        if not segs:
            segs = [(0, 0)]
        return segs, max_q

    @given(packings())
    @settings(max_examples=40, deadline=None)
    def test_random_mixed_packings_hypothesis(case):
        segs, max_q = case
        _assert_kernel_matches_oracle(segs, max_q)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_random_mixed_packings_hypothesis():
        pass
