"""Rebuilt ServeEngine: concurrent batched prefills, device-side sampling,
eos / max_seq early exit with slot reuse, and metrics sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.serving import EngineConfig, Request, ServeEngine
from repro.serving.sampling import SamplingConfig, sample, sample_slots

from conftest import tiny_dense_spec


@pytest.fixture(scope="module")
def served():
    spec = tiny_dense_spec()
    model = build_model(spec, mesh=None, param_dtype=jnp.float32,
                        compute_dtype=jnp.float32)
    params = model.init(jax.random.key(7))
    return spec, model, params


def _greedy_reference(model, params, prompt, n, max_seq=128):
    """Token-by-token greedy decode as ground truth (the seed engine's
    single-request output — its tests assert this same equivalence)."""
    cache = model.init_cache(1, max_seq)
    logits, cache = model.prefill(
        params, jnp.asarray([prompt], jnp.int32), cache=cache)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n - 1):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(logits[0])))
    return out


def test_concurrent_prefills_mixed_lengths_match_reference(served):
    """Mixed prompt lengths force concurrent prefill rows through both the
    full-width batched path and per-width partial-chunk groups; every
    request must still decode exactly the reference tokens."""
    spec, model, params = served
    rng = np.random.default_rng(3)
    lengths = [3, 11, 4, 17, 9, 5, 23, 8]
    prompts = [[int(t) for t in rng.integers(0, spec.vocab, size=n)]
               for n in lengths]
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=4, max_seq=64, chunk_size=4,
                                   prefill_rows=3))
    reqs = eng.serve([Request(prompt=p, max_new_tokens=6) for p in prompts])
    assert all(r.state == "done" for r in reqs)
    for p, r in zip(prompts, reqs):
        assert r.output == _greedy_reference(model, params, p, 6), \
            "batched prefill changed outputs"


def test_greedy_equivalence_fixed_prompt_set(served):
    """Acceptance fixture: fixed prompt set, greedy outputs must be
    token-identical to sequential reference decoding (= seed engine)."""
    spec, model, params = served
    prompts = [[5, 9, 2, 17, 33, 4, 8, 1], [7, 7, 7], [100, 3, 50, 2, 1],
               [42] * 10]
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=2, max_seq=64, chunk_size=4,
                                   prefill_rows=2))
    reqs = eng.serve([Request(prompt=p, max_new_tokens=8) for p in prompts])
    for p, r in zip(prompts, reqs):
        assert r.output == _greedy_reference(model, params, p, 8)


def test_eos_early_exit_and_slot_reuse(served):
    spec, model, params = served
    prompt = [5, 9, 2, 17, 33, 4]
    want = _greedy_reference(model, params, prompt, 12)
    eos = want[4]
    stop = want.index(eos)  # first occurrence ends the request
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=2, max_seq=64, chunk_size=4))
    # 5 identical requests over 2 slots: early exit must recycle slots
    reqs = eng.serve([Request(prompt=list(prompt), max_new_tokens=12,
                              eos_id=eos) for _ in range(5)])
    for r in reqs:
        assert r.state == "done"
        assert r.output == want[:stop + 1]
    assert sorted(eng.free_slots) == [0, 1]  # all slots back in the pool
    assert not eng.active and not eng.queue


def test_max_seq_early_exit(served):
    spec, model, params = served
    prompt = list(range(1, 11))  # 10 tokens
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=1, max_seq=16, chunk_size=4,
                                   prefill_rows=1))
    [req] = eng.serve([Request(prompt=prompt, max_new_tokens=64)])
    assert req.state == "done"
    # lengths hit max_seq-1: prefill(10) + first token + 5 decode steps
    assert len(req.output) == 16 - 10


def test_single_transfer_per_decode_step(served):
    """The rebuilt decode path makes exactly one device->host transfer per
    step regardless of how many slots are active."""
    spec, model, params = served
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=4, max_seq=64, chunk_size=8))
    reqs = [Request(prompt=[1 + i, 2, 3], max_new_tokens=4)
            for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    calls = {"n": 0}
    orig = jax.device_get

    def counting_device_get(x, *a, **kw):
        calls["n"] += 1
        return orig(x, *a, **kw)

    eng2 = ServeEngine(model, params,
                       EngineConfig(max_slots=4, max_seq=64, chunk_size=8))
    for i in range(4):
        eng2.submit(Request(prompt=[1 + i, 2, 3], max_new_tokens=4))
    eng2.run(max_steps=1)  # all 4 prompts admitted is fine; warm caches
    jax.device_get = counting_device_get
    try:
        before = calls["n"]
        eng2._decode_step()
        assert calls["n"] - before == 1
    finally:
        jax.device_get = orig


def test_ttft_monotone_in_queue_position(served):
    """Under decode_priority, earlier-queued equal-length requests get
    first tokens no later than later-queued ones (steps and wall-clock)."""
    spec, model, params = served
    prompts = [[3 + i, 1, 4, 1, 5, 9] for i in range(6)]
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=2, max_seq=64, chunk_size=4,
                                   prefill_rows=1, record_step_log=True))
    reqs = eng.serve([Request(prompt=p, max_new_tokens=4) for p in prompts])
    ttfts = [r.ttft_steps for r in sorted(reqs, key=lambda r: r.rid)]
    assert ttfts == sorted(ttfts), ttfts
    walls = [r.ttft_s for r in sorted(reqs, key=lambda r: r.rid)]
    assert all(w >= 0 for w in walls)
    assert walls == sorted(walls), walls


def test_metrics_sanity(served):
    spec, model, params = served
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=3, max_seq=64, chunk_size=4,
                                   record_step_log=True))
    reqs = eng.serve([Request(prompt=[1, 2, 3, 4, 5], max_new_tokens=5)
                      for _ in range(5)])
    m = eng.metrics.summary(reqs)
    assert m["generated_tokens"] == sum(len(r.output) for r in reqs) == 25
    assert m["tokens_per_s"] > 0 and m["wall_s"] > 0
    assert 0 < m["mean_slot_occupancy"] <= 1
    assert m["requests_done"] == 5
    assert m["ttft_s_mean"] > 0 and m["ttft_s_p95"] >= m["ttft_s_p50"]
    assert m["tpot_s_mean"] > 0
    assert m["prefill_calls"] >= 1 and m["prefill_tokens"] == 25
    assert len(eng.metrics.step_log) == eng.steps


def test_sample_slots_matches_sample_rowwise():
    """Per-slot device sampling must agree with the config-based oracle."""
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    keys = jax.random.split(jax.random.key(5), 4)
    cfgs = [SamplingConfig(),  # greedy
            SamplingConfig(temperature=0.7),
            SamplingConfig(temperature=1.0, top_k=5),
            SamplingConfig(temperature=0.5, top_p=0.8)]
    temps = jnp.asarray([c.temperature for c in cfgs])
    topks = jnp.asarray([c.top_k for c in cfgs], jnp.int32)
    topps = jnp.asarray([c.top_p for c in cfgs])
    got = sample_slots(logits, keys, temps, topks, topps)
    for i, c in enumerate(cfgs):
        want = sample(logits[i:i + 1], keys[i], c)
        assert int(got[i]) == int(want[0]), (i, c)


def test_decode_feed_stays_on_device(served):
    """Steady-state decode never re-uploads the host token mirror: the
    sampled tokens feed the next step from the donated device buffer.
    Corrupting the host mirror mid-decode must not change outputs."""
    spec, model, params = served
    prompt = [5, 9, 2, 17, 33, 4]
    want = _greedy_reference(model, params, prompt, 10)
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=2, max_seq=64, chunk_size=8,
                                   prefill_rows=1))
    [req] = [Request(prompt=list(prompt), max_new_tokens=10)]
    eng.submit(req)
    while not eng.active:
        eng.step()
    eng.step()  # one decode step: the device feed buffer is now primed
    assert eng._dev_tokens is not None
    eng._tokens[:] = 0  # corrupt the host mirror: it must not be read
    eng.run()
    assert req.state == "done" and req.output == want


# ---------------------------------------------------------------------------
# unified token-packed step
# ---------------------------------------------------------------------------

def _unified_cfg(unified, **kw):
    base = dict(max_slots=4, max_seq=64, chunk_size=4, prefill_rows=2,
                cache_layout="paged", page_size=8, unified=unified)
    base.update(kw)
    return EngineConfig(**base)


def test_unified_matches_two_dispatch_mixed_workload(served):
    """Acceptance: greedy outputs token-identical between the unified
    (one-dispatch) step and the retained two-dispatch path on a mixed
    prompt-length workload with concurrent prefills, and both match the
    sequential reference."""
    spec, model, params = served
    rng = np.random.default_rng(11)
    lengths = [3, 11, 4, 17, 9, 5, 23, 8, 2, 13]
    prompts = [[int(t) for t in rng.integers(0, spec.vocab, size=n)]
               for n in lengths]

    outs = {}
    for unified in (False, True):
        eng = ServeEngine(model, params, _unified_cfg(unified))
        reqs = eng.serve([Request(prompt=list(p), max_new_tokens=6)
                          for p in prompts])
        assert all(r.state == "done" for r in reqs)
        outs[unified] = [r.output for r in reqs]
    assert outs[True] == outs[False], "unified step changed outputs"
    for p, out in zip(prompts, outs[True]):
        assert out == _greedy_reference(model, params, p, 6)


def test_unified_matches_two_dispatch_under_preemption(served):
    """A pool small enough to force victim preemption mid-decode must
    still produce token-identical outputs in both implementations."""
    spec, model, params = served
    rng = np.random.default_rng(5)
    prompts = [[int(t) for t in rng.integers(0, spec.vocab, size=n)]
               for n in [13, 11, 14, 12, 9, 15]]
    outs, engines = {}, {}
    for unified in (False, True):
        eng = ServeEngine(model, params,
                          _unified_cfg(unified, max_seq=32, page_size=4,
                                       n_pages=11))
        reqs = eng.serve([Request(prompt=list(p), max_new_tokens=10)
                          for p in prompts])
        assert all(r.state == "done" for r in reqs)
        outs[unified] = [r.output for r in reqs]
        engines[unified] = eng
    assert outs[True] == outs[False]
    assert engines[True].metrics.preemptions \
        == engines[False].metrics.preemptions > 0


def test_unified_matches_two_dispatch_quantized_kv(served):
    """The int8 KV path quantizes per token either way (scratch-then-
    scatter vs direct-to-page), so outputs must stay identical too."""
    spec, _, _ = served
    model = build_model(spec, mesh=None, param_dtype=jnp.float32,
                        compute_dtype=jnp.float32, kv_quant=True)
    params = model.init(jax.random.key(7))
    prompts = [[5, 9, 2, 17, 33], [7, 7, 7], [42] * 9, [3, 1, 4, 1, 5, 9]]
    outs = {}
    for unified in (False, True):
        eng = ServeEngine(model, params, _unified_cfg(unified))
        reqs = eng.serve([Request(prompt=list(p), max_new_tokens=5)
                          for p in prompts])
        assert all(r.state == "done" for r in reqs)
        outs[unified] = [r.output for r in reqs]
    assert outs[True] == outs[False]


def test_unified_one_dispatch_one_transfer_per_step(served):
    """Acceptance: with >= 2 concurrent prefills in flight, every unified
    step issues exactly one jitted dispatch and one device->host
    transfer (the two-dispatch path needs strictly more)."""
    spec, model, params = served
    eng = ServeEngine(model, params, _unified_cfg(True))
    # two long prompts + short ones: prefills overlap across steps
    prompts = [[1 + i] * 14 for i in range(2)] + [[7, 8, 9], [4, 5]]
    for p in prompts:
        eng.submit(Request(prompt=list(p), max_new_tokens=5))
    eng.step()  # admit both long prompts; first packed step
    assert len(eng._prefills) >= 2, "need >= 2 concurrent prefills"
    base_d, base_t = eng.metrics.dispatches, eng.metrics.transfers_d2h
    assert base_d == eng.metrics.steps == base_t

    # count raw device->host pulls for one step while prefills overlap
    calls = {"n": 0}
    orig = jax.device_get

    def counting_device_get(x, *a, **kw):
        calls["n"] += 1
        return orig(x, *a, **kw)

    jax.device_get = counting_device_get
    try:
        eng.step()
    finally:
        jax.device_get = orig
    assert len(eng._prefills) >= 1  # the long prefills span several steps
    assert calls["n"] == 1, f"{calls['n']} device->host transfers in a step"
    assert eng.metrics.dispatches == base_d + 1
    eng.run()
    assert all(r.state == "done" for r in eng.finished)
    m = eng.metrics
    assert m.dispatches == m.steps == m.transfers_d2h


def test_unified_requires_paged_and_attention_only(served):
    spec, model, params = served
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(model, params,
                    EngineConfig(max_slots=2, max_seq=64, unified=True))


def test_unified_overlong_prompt_raises_named_error(served):
    """Satellite: a prompt that can never fit max_pages * page_size must
    raise a ValueError naming the request and the capacity — not fail
    inside the kernel index map."""
    spec, model, params = served
    eng = ServeEngine(model, params, _unified_cfg(True, max_seq=32,
                                                  page_size=8))
    with pytest.raises(ValueError, match=r"request 0: .*32 tokens"):
        eng.submit(Request(prompt=list(range(1, 60)), max_new_tokens=4))
    # the pack-time guard fires too (e.g. a resumed request that grew)
    req = Request(prompt=[1, 2, 3], max_new_tokens=4)
    eng.submit(req)
    eng._admit()
    req.output = list(range(40))  # simulate impossible growth
    with pytest.raises(ValueError, match=r"request 1: .*capacity of 32"):
        eng._unified_step()


def test_unified_sampling_smoke(served):
    """Stochastic configs run through the unified sampler (values differ
    from the two-dispatch path's RNG stream, but must be valid)."""
    spec, model, params = served
    eng = ServeEngine(model, params, _unified_cfg(True))
    reqs = eng.serve([
        Request(prompt=[5, 9, 2], max_new_tokens=6),
        Request(prompt=[8, 1, 3], max_new_tokens=6,
                sampling=SamplingConfig(temperature=0.8, top_k=20)),
    ])
    assert reqs[0].output == _greedy_reference(model, params, [5, 9, 2], 6)
    for r in reqs:
        assert r.state == "done" and len(r.output) == 6
        assert all(0 <= t < spec.vocab for t in r.output)


def test_mixed_sampling_configs_one_batch(served):
    """Greedy and stochastic requests share one engine batch; the greedy
    ones still match the reference exactly."""
    spec, model, params = served
    greedy_prompt = [5, 9, 2, 17]
    want = _greedy_reference(model, params, greedy_prompt, 6)
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=3, max_seq=64, chunk_size=4))
    reqs = [Request(prompt=list(greedy_prompt), max_new_tokens=6),
            Request(prompt=[8, 1, 3], max_new_tokens=6,
                    sampling=SamplingConfig(temperature=0.8, top_k=20)),
            Request(prompt=[2, 4, 6, 8], max_new_tokens=6,
                    sampling=SamplingConfig(temperature=1.0, top_p=0.9))]
    eng.serve(reqs)
    assert reqs[0].output == want
    for r in reqs:
        assert r.state == "done" and len(r.output) == 6
        assert all(0 <= t < spec.vocab for t in r.output)


# ---------------------------------------------------------------------------
# debug guards (transfer_guard + retrace assertion)
# ---------------------------------------------------------------------------

def test_debug_guards_unified_matches_guard_off(served):
    """Acceptance: a debug_guards engine completes a mixed prefill+decode
    workload with the transfer guard active, asserts zero steady-state
    retraces, and its greedy outputs are token-identical to guard-off."""
    spec, model, params = served
    prompts = [[5, 9, 2, 17, 33], [7, 7, 7], [42] * 9, [3, 1, 4, 1, 5, 9]]
    outs = {}
    for guards in (False, True):
        eng = ServeEngine(model, params,
                          _unified_cfg(True, debug_guards=guards))
        reqs = eng.serve([Request(prompt=list(p), max_new_tokens=5)
                          for p in prompts])
        assert all(r.state == "done" for r in reqs)
        outs[guards] = [r.output for r in reqs]
    assert outs[True] == outs[False]


def test_debug_guards_two_dispatch_slot_churn(served):
    """The guard + flat-jit-cache assertion must also hold on the
    two-dispatch path across slot churn (requests finishing and new ones
    being admitted re-use slots without retracing)."""
    spec, model, params = served
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=2, max_seq=64, chunk_size=8,
                                   debug_guards=True))
    reqs = [Request(prompt=[1 + i, 2, 3], max_new_tokens=3 + i % 3)
            for i in range(5)]  # > max_slots: forces churn
    eng.serve(reqs)
    assert all(r.state == "done" for r in reqs)
    # the steady-state dispatch traced exactly once and stayed flat
    assert eng._trace_sizes.get("_jit_decode", 0) >= 1


def test_debug_guards_transfer_guard_is_active(served):
    """The guard must actually be armed: an implicit transfer inside the
    step (a numpy array fed straight into a jitted call) has to raise."""
    spec, model, params = served
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=2, max_seq=64, chunk_size=8,
                                   debug_guards=True))
    eng.submit(Request(prompt=[5, 9, 2], max_new_tokens=4))
    while not eng.active:
        eng.step()
    eng.step()  # steady state under the guard: must be clean
    with eng._step_guard():
        with pytest.raises(Exception, match="[Dd]isallow"):
            # an implicit host->device transfer: numpy straight into jit
            jax.jit(lambda x: x + 1)(np.zeros((4,), np.float32))


def test_debug_guards_retrace_assertion_fires(served):
    """_assert_no_retrace must detect a growing jit cache (seeded by
    calling a steady-state dispatchee at a shape the engine never uses)."""
    spec, model, params = served
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=2, max_seq=64, chunk_size=8,
                                   debug_guards=True))
    eng.submit(Request(prompt=[5, 9, 2], max_new_tokens=4))
    while not eng.active:
        eng.step()
    eng.step()
    if getattr(eng._jit_decode, "_cache_size", None) is None:
        pytest.skip("jax version exposes no jit cache introspection")
    # poison the cache: an off-geometry trace of the same jitted callable
    cache2 = model.init_cache(eng.cfg.max_slots, 32, layout="dense")
    eng._jit_decode(params, cache2,
                    jnp.zeros((eng.cfg.max_slots, 1), jnp.int32),
                    jax.random.key(1), jnp.zeros((eng.cfg.max_slots,)),
                    jnp.zeros((eng.cfg.max_slots,), jnp.int32),
                    jnp.ones((eng.cfg.max_slots,)))
    with pytest.raises(AssertionError, match="retrace"):
        eng._assert_no_retrace()
