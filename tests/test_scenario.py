"""The declarative Scenario layer: JSON round-trips across all four modes,
sweep construction + pruning, the analytical backend's equivalence with
the direct stage calls, parallel == serial execution, and the
analytical-vs-engine schema unification on a tiny runnable model."""

import json
import math

import pytest

from repro.core import Optimizations, ParallelismConfig, Workload, paper_model
from repro.core.stages import decode, estimate, prefill
from repro.core.usecases import use_case
from repro.scenario import (ChunkedSpec, DisaggSpec, METRIC_FIELDS, Report,
                            Scenario, SpeculativeSpec, Sweep, compare,
                            feasible, resolve_platform, run)

FP8 = dict(weight_dtype="fp8", act_dtype="fp8", kv_dtype="fp8")


def _base(**kw):
    defaults = dict(use_case="chat", batch=4, platform="hgx-h100x8",
                    parallelism=dict(tp=8), opt=FP8)
    defaults.update(kw)
    return Scenario.make("llama3-8b", **defaults)


# ---------------------------------------------------------------------------
# JSON round trip (all four modes, inline refs)
# ---------------------------------------------------------------------------

def _mode_scenarios():
    base = _base()
    return [
        base,
        base.replace(mode="chunked",
                     chunked=ChunkedSpec(chunk=256, decode_batch=8)),
        base.replace(mode="speculative",
                     speculative=SpeculativeSpec(draft="llama2-7b", n=4,
                                                 gamma=0.9)),
        base.replace(mode="disaggregated",
                     disaggregated=DisaggSpec(total_npus=8,
                                              tp_options=(1, 2, 4))),
    ]


@pytest.mark.parametrize("sc", _mode_scenarios(),
                         ids=[s.mode for s in _mode_scenarios()])
def test_json_roundtrip_all_modes(sc):
    blob = sc.to_json()
    back = Scenario.from_json(blob)
    assert back == sc
    # and the payload is genuine JSON (no repr smuggling)
    assert isinstance(json.loads(blob), dict)


def test_json_roundtrip_inline_model_and_platform(tiny_spec):
    plat = resolve_platform("gb200x8")
    sc = Scenario.make(tiny_spec, workload=Workload(batch=2, tau_p=16,
                                                    tau_d=8),
                       batch=2, platform=plat)
    back = Scenario.from_json(sc.to_json())
    assert back == sc
    assert back.resolve_model() == tiny_spec
    assert back.resolve_platform() == plat


def test_report_json_roundtrip():
    rep = run([_base()])[0]
    assert Report.from_json(rep.to_json()) == rep


def test_mode_validation():
    with pytest.raises(ValueError, match="unknown mode"):
        _base().replace(mode="warp-drive")
    with pytest.raises(ValueError, match="speculative"):
        _base().replace(mode="speculative")  # no draft


def test_unknown_refs_raise_with_candidates():
    with pytest.raises(ValueError, match="platform"):
        resolve_platform("not-a-platform")
    with pytest.raises(ValueError, match="valid use cases"):
        use_case("typo")
    from repro.configs import registry
    with pytest.raises(ValueError, match="assigned archs"):
        registry.get_spec("typo")


# ---------------------------------------------------------------------------
# Sweep grids + pruning
# ---------------------------------------------------------------------------

def test_sweep_grid_size_and_order():
    grid = Sweep(_base()).over(model=["llama3-8b", "llama3-70b"],
                               tp=[1, 2, 4])
    assert grid.size_unpruned == 6
    scs = grid.scenarios(prune=False)
    assert len(scs) == 6
    # first axis is the outer loop
    assert [s.model_name for s in scs[:3]] == ["llama3-8b"] * 3
    assert [s.parallelism.tp for s in scs[:3]] == [1, 2, 4]


def test_sweep_prunes_infeasible_tp():
    # hgx-h100x8 has 8 NPUs: tp=16/32 can never run there
    grid = Sweep(_base()).over(tp=[1, 2, 4, 8, 16, 32])
    kept, dropped = grid.partition()
    assert [s.parallelism.tp for s in kept] == [1, 2, 4, 8]
    assert [s.parallelism.tp for s in dropped] == [16, 32]
    assert all(feasible(s) for s in kept)
    assert not any(feasible(s) for s in dropped)


def test_sweep_keeps_oom_points():
    """OOM is a result (paper Fig. 17), not a constraint violation."""
    sc = Scenario.make("llama3-405b",
                       workload=Workload(batch=256, tau_p=100_000,
                                         tau_d=1000),
                       batch=256, platform="hgx-h100x8",
                       parallelism=dict(tp=8), opt=FP8)
    assert feasible(sc)
    rep, = run([sc])
    assert rep.status == "oom"
    assert rep.fits_memory is False


def test_sweep_rejects_unknown_axis():
    with pytest.raises(ValueError, match="unknown sweep axis"):
        Sweep(_base()).over(warp_factor=[9])


def test_sweep_where_predicate_prunes():
    """Capacity-style grids can cut points (e.g. prompts beyond the
    sequence budget) instead of reporting them as OOM results."""
    max_seq = 8192
    grid = (Sweep(_base()).over(tau_p=[1024, 4096, 16384, 65536])
            .where(lambda sc: sc.workload.tau_p <= max_seq))
    kept, dropped = grid.partition()
    assert [s.workload.tau_p for s in kept] == [1024, 4096]
    assert [s.workload.tau_p for s in dropped] == [16384, 65536]
    # predicates AND together and compose with feasibility pruning
    both = (Sweep(_base()).over(tau_p=[1024, 4096], tp=[1, 16])
            .where(lambda sc: sc.workload.tau_p <= max_seq)
            .where(lambda sc: sc.workload.tau_p >= 2048))
    assert [(s.workload.tau_p, s.parallelism.tp)
            for s in both.scenarios()] == [(4096, 1)]


def test_sweep_where_rejects_non_callable():
    with pytest.raises(TypeError, match="callable"):
        Sweep(_base()).where(42)


def test_sweep_whole_object_axes():
    """workload=/opt=/parallelism= axes sweep the whole sub-object (and
    compose with field shortcuts refining them)."""
    wls = [Workload(batch=2, tau_p=128, tau_d=16),
           Workload(batch=8, tau_p=512, tau_d=64)]
    scs = Sweep(_base()).over(workload=wls,
                              opt=[Optimizations(),
                                   Optimizations(**FP8)]).scenarios()
    assert len(scs) == 4
    assert [s.workload.tau_p for s in scs] == [128, 128, 512, 512]
    assert {s.opt.weight_dtype for s in scs} == {"bf16", "fp8"}
    # shortcut refines the swept object
    scs = Sweep(_base()).over(workload=wls, batch=[1]).scenarios()
    assert all(s.workload.batch == 1 for s in scs)
    scs = Sweep(_base()).over(
        parallelism=[ParallelismConfig(tp=2), ParallelismConfig(tp=4)]
    ).scenarios()
    assert [s.parallelism.tp for s in scs] == [2, 4]


def test_make_keeps_explicit_workload_batch():
    wl = Workload(batch=32, tau_p=100, tau_d=10)
    assert Scenario.make("llama3-8b", workload=wl).workload.batch == 32
    assert Scenario.make("llama3-8b", workload=wl,
                         batch=4).workload.batch == 4


def test_sweep_use_case_axis_keeps_batch():
    scs = Sweep(_base(batch=16)).over(
        use_case=["chat", "qa_rag"]).scenarios()
    assert [s.workload.name for s in scs] == ["chat", "qa_rag"]
    assert all(s.workload.batch == 16 for s in scs)


# ---------------------------------------------------------------------------
# Analytical backend: equivalence with the direct stage calls
# ---------------------------------------------------------------------------

def test_analytical_matches_direct_stage_calls():
    sc = _base()
    rep, = run([sc])
    spec = paper_model("llama3-8b")
    plat = resolve_platform("hgx-h100x8")
    par, opt = ParallelismConfig(tp=8), Optimizations(**FP8)
    wl = use_case("chat", batch=4)
    pre = prefill(spec, plat, par, opt, wl)
    dec = decode(spec, plat, par, opt, wl)
    assert rep.status == "ok"
    assert rep.ttft_s == pre.time
    assert rep.tpot_s == dec.meta["tpot"]
    assert math.isclose(rep.latency_s, pre.time + dec.meta["tpot"] * wl.tau_d,
                        rel_tol=1e-12)
    old = estimate(spec, plat, par, opt, wl)
    assert rep.throughput_tok_s == old.throughput
    assert rep.energy_j == old.energy
    assert rep.extra["decode"]["tokens_per_s"] == dec.meta["tokens_per_s"]


def test_infeasible_scenario_reports_not_raises():
    sc = Scenario(model="llama3-8b", workload=use_case("chat", 1),
                  platform="hgx-h100x8",
                  parallelism=ParallelismConfig(tp=64))
    rep, = run([sc])
    assert rep.status == "infeasible"
    assert "64" in rep.error


def test_parallel_equals_serial():
    grid = Sweep(_base()).over(model=["llama3-8b", "llama3-70b"],
                               tp=[1, 2, 4, 8],
                               use_case=["chat", "qa_rag"])
    scs = grid.scenarios()
    assert len(scs) == 16
    serial = run(scs, max_workers=1)
    parallel = run(scs, max_workers=2)
    assert serial == parallel


def test_deprecated_genz_shim_still_works():
    from repro.core import GenZ
    from repro.core import genz as genz_mod
    g = GenZ.hgx_h100(8).with_opt(**FP8)
    genz_mod.reset_deprecation_warnings()
    with pytest.warns(DeprecationWarning):
        old = g.estimate("llama3-8b", use_case="chat", batch=4,
                         parallelism=dict(tp=8))
    rep, = run([_base()])
    assert old.ttft == rep.ttft_s and old.tpot == rep.tpot_s


def test_deprecated_genz_warning_is_one_shot(recwarn):
    """The shim nags once per method per process, not per call."""
    import warnings as _w
    from repro.core import GenZ
    from repro.core import genz as genz_mod
    g = GenZ.hgx_h100(8).with_opt(**FP8)
    genz_mod.reset_deprecation_warnings()
    with _w.catch_warnings(record=True) as caught:
        _w.simplefilter("always")
        for _ in range(3):
            g.estimate("llama3-8b", use_case="chat", batch=4,
                       parallelism=dict(tp=8))
    deps = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1
    assert "Scenario" in str(deps[0].message)


# ---------------------------------------------------------------------------
# Engine backend: the analytical/measured bridge
# ---------------------------------------------------------------------------

ENGINE_KW = dict(max_slots=4, max_seq=64, max_prompt=12, max_new=6,
                 prefill_rows=2)


def _tiny_scenario(tiny_spec, **kw):
    return Scenario.make(tiny_spec,
                         workload=Workload(batch=3, tau_p=12, tau_d=6),
                         batch=3, **kw)


def test_engine_vs_analytical_same_schema(tiny_spec):
    """The acceptance one-liner: both backends fill the same Report schema
    for the same Scenario, so predicted-vs-measured is compare(a, b)."""
    sc = _tiny_scenario(tiny_spec)
    ana, = run([sc], backend="analytical")
    eng, = run([sc], backend="engine", engine_kw=ENGINE_KW)
    assert ana.status == "ok" and eng.status == "ok"
    assert ana.backend == "analytical" and eng.backend == "engine"
    assert set(ana.metrics()) == set(eng.metrics()) == set(METRIC_FIELDS)
    # the shared serving metrics are populated on both sides
    for f in ("ttft_s", "tpot_s", "throughput_tok_s"):
        assert getattr(ana, f) is not None, f
        assert getattr(eng, f) is not None, f
        assert getattr(eng, f) > 0, f
    errs = compare(ana, eng)
    assert "throughput_tok_s" in errs and errs["throughput_tok_s"] >= 0
    # measured run really came from the engine
    assert eng.extra["engine"]["generated_tokens"] > 0
    assert eng.extra["engine"]["requests_done"] == 3
    # and the measured report survives JSON
    assert Report.from_json(eng.to_json()) == eng


def test_engine_backend_chunked_mode(tiny_spec):
    sc = _tiny_scenario(tiny_spec, mode="chunked",
                        chunked=ChunkedSpec(chunk=4, decode_batch=2))
    rep, = run([sc], backend="engine", engine_kw=ENGINE_KW)
    assert rep.status == "ok"
    assert rep.extra["engine_config"]["chunk_size"] == 4
    assert rep.extra["engine"]["prefill_calls"] >= 3  # 12 tokens / 4-chunks


def test_engine_backend_disaggregated_lowers(tiny_spec):
    """mode='disaggregated' is no longer refused: it lowers to a live
    two-engine DisaggCluster and reports migration traffic."""
    disagg = _tiny_scenario(tiny_spec, mode="disaggregated")
    rep, = run([disagg], backend="engine", engine_kw=ENGINE_KW)
    assert rep.status == "ok", rep.error
    eng = rep.extra["engine"]
    assert eng["migrations"] > 0 and eng["migrated_bytes"] > 0
    assert eng["requests_done"] == 3
    cfg = rep.extra["engine_config"]
    assert cfg["prefill_rows"] >= 1 and cfg["decode_slots"] >= 1
    assert cfg["prefill_rows"] + cfg["decode_slots"] == cfg["budget_slots"]
    # planner plumbing: the best plan AND the colocated baseline surface
    assert rep.extra["colocated"] is not None
    assert rep.extra["measured_kv_transfer_s"] >= 0
    assert Report.from_json(rep.to_json()) == rep


def test_engine_backend_unsupported_and_errors(tiny_spec):
    from repro.scenario.engine_backend import LOWERABLE_MODES
    # every Scenario mode now lowers (speculative included, to the
    # batched unified engine); full paper models still refuse
    assert set(LOWERABLE_MODES) == {"monolithic", "chunked", "speculative",
                                    "disaggregated"}
    spec_sc = _tiny_scenario(
        tiny_spec, mode="speculative",
        speculative=SpeculativeSpec(draft="llama2-7b", n=2))
    rep, = run([spec_sc], backend="engine",
               engine_kw=dict(ENGINE_KW, unified=True))
    assert rep.status == "error"  # the DRAFT ref is a full paper model
    assert "reduced" in rep.error
    # tp/pp under speculation refuses with the named constraint
    spec_tp = _tiny_scenario(
        tiny_spec, mode="speculative",
        speculative=SpeculativeSpec(draft=tiny_spec, n=2),
        parallelism=dict(tp=2))
    rep, = run([spec_tp], backend="engine", engine_kw=dict(ENGINE_KW))
    assert rep.status == "error"
    assert "single-device" in rep.error
    # a split needs >= 2 engine units: the error names the missing knob
    disagg = _tiny_scenario(tiny_spec, mode="disaggregated")
    rep, = run([disagg], backend="engine",
               engine_kw=dict(ENGINE_KW, max_slots=1))
    assert rep.status == "error"
    assert "max_slots" in rep.error
    paper = Scenario.make("llama3-70b", use_case="chat", batch=1)
    rep, = run([paper], backend="engine")
    assert rep.status == "error"
    assert "reduced" in rep.error


def test_run_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown backend"):
        run([_base()], backend="quantum")
