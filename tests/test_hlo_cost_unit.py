"""Unit tests for the HLO-text cost analyzer (pure parsing, no compiles)."""

import pytest

pytest.importorskip("hypothesis", reason="dev extra; see requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.launch import hlo_cost
from repro.launch.hlo_cost import (Cost, HloCostModel, is_float_type,
                                   shape_bytes, shape_elems)


def test_shape_bytes():
    assert shape_bytes("f32[2,3]{1,0}") == 24
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("s8[100]") == 100
    assert shape_bytes("(f32[2], s32[4])") == 8 + 16
    assert shape_bytes("pred[]") == 1
    assert shape_elems("f32[4,5,6]{2,1,0}") == 120


def test_is_float_type():
    assert is_float_type("f32[2,3]")
    assert is_float_type("bf16[1]")
    assert not is_float_type("s8[100]")
    assert not is_float_type("s32[]")


HLO = """\
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %y = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %y)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %lim = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %lim), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_while_trip_count_multiplication():
    cost = hlo_cost.analyze(HLO)
    # one 8x8x8 dot per iteration, 5 iterations
    assert cost.flops == pytest.approx(5 * 2 * 8 * 8 * 8, rel=0.2)
    assert cost.unknown_loops == 0


def test_collective_wire_accounting():
    hlo = """\
HloModule c

ENTRY %main (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64]{1,0} parameter(0)
  ROOT %ar = f32[64,64]{1,0} all-reduce(%a), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
}
"""
    cost = hlo_cost.analyze(hlo)
    size = 64 * 64 * 4
    assert cost.coll_bytes["all-reduce"] == pytest.approx(
        2 * (7 / 8) * size)


def test_iota_replica_groups():
    hlo = """\
HloModule c

ENTRY %main (a: f32[128]) -> f32[128] {
  %a = f32[128]{0} parameter(0)
  ROOT %ar = f32[128]{0} all-reduce(%a), replica_groups=[8,64]<=[512]T(1,0), to_apply=%add
}
"""
    model = HloCostModel(hlo)
    cost = model.cost()
    size = 128 * 4
    assert cost.coll_bytes["all-reduce"] == pytest.approx(
        2 * (63 / 64) * size)


def test_dynamic_slice_bills_region_not_buffer():
    hlo = """\
HloModule d

ENTRY %main (a: f32[100,256], i: s32[]) -> f32[1,256] {
  %a = f32[100,256]{1,0} parameter(0)
  %i = s32[] parameter(1)
  %z = s32[] constant(0)
  ROOT %ds = f32[1,256]{1,0} dynamic-slice(%a, %i, %z), dynamic_slice_sizes={1,256}
}
"""
    cost = hlo_cost.analyze(hlo)
    assert cost.bytes == pytest.approx(2 * 1 * 256 * 4)  # region r+w only


def test_int_bytes_tracked_separately():
    """int8-result ops (the quantized KV-cache update path) are exempt from
    the f32-twin ÷2 normalization; classification is by result dtype."""
    hlo = """\
HloModule i

ENTRY %main (c: s8[64,16], t: s8[1,16], i: s32[], b: f32[64,16]) -> s8[64,16] {
  %c = s8[64,16]{1,0} parameter(0)
  %t = s8[1,16]{1,0} parameter(1)
  %i = s32[] parameter(2)
  %b = f32[64,16]{1,0} parameter(3)
  %z = s32[] constant(0)
  %sq = f32[64,16]{1,0} multiply(%b, %b)
  ROOT %dus = s8[64,16]{1,0} dynamic-update-slice(%c, %t, %i, %z)
}
"""
    cost = hlo_cost.analyze(hlo)
    assert cost.int_bytes == pytest.approx(2 * 1 * 16)  # DUS region r+w, s8
    # float multiply traffic halves; the int8 update doesn't
    assert cost.normalized_bytes(0.5) == pytest.approx(
        (cost.bytes - cost.int_bytes) * 0.5 + cost.int_bytes)
    assert cost.normalized_bytes(0.5) > cost.bytes * 0.5


@given(dims=st.lists(st.integers(1, 64), min_size=0, max_size=4),
       dt=st.sampled_from(["f32", "bf16", "s8", "s32", "pred"]))
@settings(max_examples=50, deadline=None)
def test_shape_bytes_property(dims, dt):
    s = f"{dt}[{','.join(map(str, dims))}]"
    n = 1
    for d in dims:
        n *= d
    assert shape_bytes(s) == n * hlo_cost.DTYPE_BYTES[dt]


def test_input_specs_api():
    """The dry-run's public input_specs() contract: ShapeDtypeStructs with
    shardings, no device allocation."""
    import subprocess, sys, os, textwrap
    from pathlib import Path
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        from repro.launch.dryrun import input_specs
        import jax
        args = input_specs("qwen1.5-0.5b", "decode_32k")
        leaves = jax.tree.leaves(args)
        assert all(isinstance(x, jax.ShapeDtypeStruct) for x in leaves)
        assert all(x.sharding is not None for x in leaves)
        assert len(jax.devices()) == 512  # dryrun module forces the fleet
        print("OK", len(leaves))
    """)], capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout
