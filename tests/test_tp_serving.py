"""Mesh-sharded serving: refusal surfaces, per-shard kernel bounds, and
the sharded engine's invariants on a forced multi-device CPU mesh.

The main pytest process sees ONE device (no XLA_FLAGS), so everything
that needs a real mesh runs in a subprocess via ``run_with_devices`` —
the same pattern as tests/test_distributed.py.  In-process tests cover
the validation/refusal paths (which must fail identically on any host:
shape divisibility before device count), the analytic collective
accounting, and the concrete kernel-bounds pass at per-shard shapes.
"""

import re
import textwrap
from pathlib import Path

import pytest

from conftest import tiny_dense_spec
from repro.analysis.kernel_bounds import (KernelCase, check_kernel_bounds,
                                          default_cases, sharded_cases)
from repro.serving import EngineConfig
from repro.serving.sharded import collective_stats, validate_engine_sharding
from test_distributed import run_with_devices

FIXDIR = Path(__file__).resolve().parent / "fixtures" / "lint"


# ---------------------------------------------------------------------------
# refusal surfaces — must fail the same way on any host
# ---------------------------------------------------------------------------

def _cfg(**kw):
    base = dict(max_slots=4, max_seq=64, chunk_size=4, prefill_rows=2,
                cache_layout="paged", page_size=8, unified=True)
    base.update(kw)
    return EngineConfig(**base)


def test_refuses_non_unified():
    with pytest.raises(ValueError, match="unified"):
        validate_engine_sharding(tiny_dense_spec(), _cfg(tp=2, unified=False))


def test_refuses_indivisible_heads():
    # tiny spec has n_kv_heads=2: tp=4 cannot give every rank a kv head
    with pytest.raises(ValueError, match="n_kv_heads"):
        validate_engine_sharding(tiny_dense_spec(), _cfg(tp=4))


def test_refuses_indivisible_vocab_untied():
    with pytest.raises(ValueError, match="vocab"):
        validate_engine_sharding(
            tiny_dense_spec(vocab=255, tied_embeddings=False), _cfg(tp=2))


def test_refuses_indivisible_layer_repeats():
    with pytest.raises(ValueError, match="repeats"):
        validate_engine_sharding(tiny_dense_spec(n_layers=3), _cfg(pp=2))


def test_refuses_too_few_devices_with_recipe():
    """Device-count check comes last and names the XLA_FLAGS recipe —
    the main pytest process has exactly one visible device."""
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        validate_engine_sharding(tiny_dense_spec(), _cfg(tp=2))


def test_engine_backend_refuses_unsupported_axes():
    """A ParallelismConfig the live engine cannot lower (ep>1) surfaces
    as an error Report naming the unsupported axis and the supported
    ones."""
    from repro.core.parallelism import ParallelismConfig
    from repro.core.stages import Workload
    from repro.scenario import Scenario, run

    sc = Scenario(model=tiny_dense_spec(),
                  workload=Workload(batch=2, tau_p=8, tau_d=4),
                  parallelism=ParallelismConfig(ep=2))
    rep = run([sc], backend="engine")[0]
    assert rep.status == "error"
    assert "ep=2" in rep.error
    assert "tp" in rep.error and "pp" in rep.error


@pytest.mark.parametrize("mode", ["disaggregated", "speculative"])
def test_engine_backend_refuses_parallel_disagg_and_spec(mode):
    """Only the unified chunked path is threaded through shard_map; the
    other engine lowerings refuse sharded scenarios instead of silently
    running tp=pp=1."""
    from repro.core.parallelism import ParallelismConfig
    from repro.core.stages import Workload
    from repro.scenario import Scenario, SpeculativeSpec, run

    kw = {}
    if mode == "speculative":
        kw["speculative"] = SpeculativeSpec(
            draft=tiny_dense_spec(n_layers=1), n=2)
    sc = Scenario(model=tiny_dense_spec(), mode=mode,
                  workload=Workload(batch=2, tau_p=8, tau_d=4),
                  parallelism=ParallelismConfig(tp=2), **kw)
    rep = run([sc], backend="engine")[0]
    assert rep.status == "error"
    assert mode in rep.error and "TP=2" in rep.error


# ---------------------------------------------------------------------------
# analytic collective accounting
# ---------------------------------------------------------------------------

def test_collective_stats_counts():
    spec = tiny_dense_spec(n_heads=8, n_kv_heads=4)  # untied, 2 layers
    t_pack, n_segs = 12, 4
    coll, nbytes = collective_stats(spec, 2, 1, t_pack, n_segs, 4)
    # 2 psums per layer + 1 logits all_gather for the untied lm_head
    assert coll == 2 * spec.n_layers + 1
    # each psum moves 2(tp-1)/tp x payload; payload = t_pack*d_model*4
    assert nbytes > 2 * spec.n_layers * t_pack * spec.d_model * 4 // 2
    coll_pp, _ = collective_stats(spec, 1, 2, t_pack, n_segs, 4)
    assert coll_pp == 2 + 1  # pp ppermutes + broadcast psum
    assert collective_stats(spec, 1, 1, t_pack, n_segs, 4) == (0, 0)


# ---------------------------------------------------------------------------
# kernel bounds at per-shard shapes
# ---------------------------------------------------------------------------

def test_sharded_kernel_cases_registered_and_clean():
    """The default registry now re-checks the kernels at the local
    geometry shard_map workers see (kv heads / tp), and they pass."""
    names = [c.name for c in default_cases()]
    assert any("tp2" in n for n in names)
    assert any("tp4" in n for n in names)
    findings = check_kernel_bounds(sharded_cases())
    assert findings == [], [(f.code, f.message) for f in findings]


def test_seeded_global_head_walk_caught_at_marker():
    """The seeded fixture walks the GLOBAL kv-head axis over a per-shard
    pool; the concrete pass must flag RPL301 exactly on the marked
    ``pallas_call`` line.  (The fixture name deliberately misses the
    ``rpl*.py`` glob: AST linting cannot see value-dependent bounds.)"""
    import importlib.util

    fix = FIXDIR / "sharded_rpl301_kv_head_walk.py"
    source = fix.read_text()
    golden = {(i, code)
              for i, line in enumerate(source.splitlines(), 1)
              for m in [re.search(r"#\s*EXPECT:\s*(RPL\d+)", line)] if m
              for code in [m.group(1)]}
    assert golden, "fixture lost its EXPECT markers"

    mspec = importlib.util.spec_from_file_location("sharded_fix", fix)
    mod = importlib.util.module_from_spec(mspec)
    mspec.loader.exec_module(mod)
    findings = check_kernel_bounds(
        [KernelCase("sharded_kv_head_walk", mod.local_shard_case)])
    got = {(f.line, f.code) for f in findings}
    assert got == golden, [(f.code, f.line, f.message) for f in findings]


# ---------------------------------------------------------------------------
# the sharded engine itself — forced multi-device subprocesses
# ---------------------------------------------------------------------------

_PRELUDE = """\
import jax, jax.numpy as jnp
from repro.core.modelspec import AttnSpec, ModelSpec
from repro.models import build_model
from repro.serving import EngineConfig, Request, ServeEngine

spec = ModelSpec(name="t8", d_model=64, n_layers=2, n_heads=8,
                 n_kv_heads=4, d_head=16, d_ff=128, vocab=256,
                 attn=AttnSpec(kind="full", causal=True))
model = build_model(spec, mesh=None, param_dtype=jnp.float32,
                    compute_dtype=jnp.float32)
params = model.init(jax.random.key(0))

def run(tp, pp, n_pages=None, prefix=False, prompts=None, guards=True):
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=4, max_seq=64, chunk_size=4,
                                   prefill_rows=2, cache_layout="paged",
                                   page_size=8, unified=True, tp=tp,
                                   pp=pp, n_pages=n_pages,
                                   prefix_cache=prefix,
                                   debug_guards=guards))
    if prompts is None:
        prompts = [[7, 8, 9] + list(range(1, 10 + i)) for i in range(6)]
    reqs = [Request(prompt=list(p), max_new_tokens=6) for p in prompts]
    eng.serve(reqs)
    m = eng.metrics
    if prefix:  # CoW page copies are admission-time device dispatches
        assert m.dispatches >= m.steps, (m.dispatches, m.steps)
    else:
        assert m.dispatches == m.steps, (m.dispatches, m.steps)
    assert m.transfers_d2h == m.steps, (m.transfers_d2h, m.steps)
    return [r.output for r in reqs], m, eng
"""


def _mesh_run(n_devices: int, body: str) -> str:
    """Compose the zero-indent prelude with a dedented test body so
    ``run_with_devices``'s dedent is a no-op and the body really
    executes at module level (an indented body would silently become
    part of the prelude's last function)."""
    code = _PRELUDE + textwrap.dedent(body)
    out = run_with_devices(n_devices, code)
    assert "OK" in out, f"subprocess body did not run to its print: {out!r}"
    return out


def test_token_identity_counters_and_collectives_across_meshes():
    """tp=4, tp=2 x pp=2 and pp=2 all decode the exact tokens of the
    single-device engine, keep one dispatch + one d2h pull per step,
    and report the analytically-predicted collective count per step
    (2 psums/layer + 1 logits gather under tp; pp hops + broadcast
    under pp) — all with debug_guards trapping implicit transfers."""
    _mesh_run(8, """
        base, _, _ = run(1, 1)
        want = {(4, 1): 5.0, (2, 2): 8.0, (1, 2): 3.0}
        for (tp, pp), coll_per_step in want.items():
            out, m, _ = run(tp, pp)
            assert out == base, (tp, pp)
            assert m.collectives / m.steps == coll_per_step, \\
                (tp, pp, m.collectives, m.steps)
            assert m.collective_bytes > 0
        print("OK")
    """)


def test_preemption_recompute_identical_under_tp():
    """A starved page pool forces preemption + recompute; the sharded
    engine must preempt the same way and still match tp=1 greedy
    outputs token for token."""
    _mesh_run(2, """
        o1, m1, _ = run(1, 1, n_pages=9)
        o2, m2, _ = run(2, 1, n_pages=9)
        assert m2.preemptions > 0, m2
        assert m1.preemptions == m2.preemptions
        assert o1 == o2
        print("OK", m2.preemptions)
    """)


def test_prefix_cache_cow_fork_identical_under_tp():
    """Identical two-full-page prompts make every later request a full
    hit that forks its tail page copy-on-write; under tp=2 the forks
    happen in the sharded pools and outputs stay token-identical."""
    _mesh_run(2, """
        prompts = [[3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8, 9, 7, 9, 3]] * 4
        o1, m1, _ = run(1, 1, prefix=True, prompts=prompts)
        o2, m2, _ = run(2, 1, prefix=True, prompts=prompts)
        assert m2.prefix_hits > 0 and m2.prefix_cow_forks > 0, m2
        assert (m1.prefix_hits, m1.prefix_cow_forks) == \\
               (m2.prefix_hits, m2.prefix_cow_forks)
        assert o1 == o2 and len(set(map(tuple, o1))) == 1
        print("OK", m2.prefix_cow_forks)
    """)


def test_page_table_bounds_and_shard_geometry():
    """Every device holds exactly its (repeats/pp, kv_heads/tp) slice of
    the pools, and every page-table entry indexes inside the local pool
    (the table is replicated; pools shard on non-page axes, so ids are
    valid on all ranks)."""
    _mesh_run(4, """
        import numpy as np
        _, _, eng = run(2, 2, prompts=[list(range(1, 12))] * 3)
        ptab = np.asarray(eng.cache.page_table)
        assert ptab.min() >= 0 and ptab.max() < eng.pager.n_pages
        k = eng.cache.layers["pos0"].k
        assert len(k.addressable_shards) == 4
        for sh in k.addressable_shards:
            assert sh.data.shape[0] == k.shape[0] // 2  # repeats / pp
            assert sh.data.shape[1] == k.shape[1]       # full page pool
            assert sh.data.shape[2] == k.shape[2] // 2  # kv heads / tp
        print("OK", k.shape, "->", tuple(sh.data.shape))
    """)
