"""Property-based tests (hypothesis) on the system's invariants."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra; see requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Collective, NetworkDim, Optimizations,
                        ParallelismConfig, paper_model)
from repro.core.network import collective_time_1d
from repro.core.profiler import PassSpec, model_ops, pass_flops, pass_bytes
from repro.core.stages import expected_tokens_per_cycle
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.training.compression import compress_roundtrip

SETTINGS = dict(max_examples=25, deadline=None)


@given(n=st.integers(2, 512), size=st.floats(1e3, 1e12),
       bw=st.floats(1e9, 1e13), lat=st.floats(1e-7, 1e-4))
@settings(**SETTINGS)
def test_collective_times_positive_and_monotone_in_size(n, size, bw, lat):
    dim = NetworkDim("x", n, bw, lat)
    for kind in Collective:
        t1 = collective_time_1d(kind, size, dim)
        t2 = collective_time_1d(kind, size * 2, dim)
        assert t1 > 0
        assert t2 >= t1


@given(n=st.integers(1, 16), gamma=st.floats(0.0, 1.0))
@settings(**SETTINGS)
def test_spec_decode_expected_tokens_bounded(n, gamma):
    e = expected_tokens_per_cycle(n, gamma)
    assert -1e-9 <= e <= n + 1e-9


@given(batch=st.integers(1, 64), seq=st.integers(16, 4096),
       tp=st.sampled_from([1, 2, 4, 8]))
@settings(**SETTINGS)
def test_profiler_flops_scale_linearly_with_tokens(batch, seq, tp):
    spec = paper_model("llama3-8b")
    par = ParallelismConfig(tp=tp)
    opt = Optimizations()
    f1 = pass_flops(model_ops(spec, PassSpec(batch, seq, seq, True), par,
                              opt, head_q_len=1))
    f2 = pass_flops(model_ops(spec, PassSpec(batch * 2, seq, seq, True), par,
                              opt, head_q_len=1))
    np.testing.assert_allclose(f2 / f1, 2.0, rtol=0.02)


@given(tp=st.sampled_from([1, 2, 4, 8, 16]))
@settings(**SETTINGS)
def test_tensor_parallel_divides_work(tp):
    spec = paper_model("llama3-70b")
    opt = Optimizations()
    base = pass_flops(model_ops(spec, PassSpec(1, 1024, 1024, True),
                                ParallelismConfig(), opt))
    shard = pass_flops(model_ops(spec, PassSpec(1, 1024, 1024, True),
                                 ParallelismConfig(tp=tp), opt))
    # per-NPU flops shrink ~1/tp (padding allows small overshoot)
    assert shard <= base / tp * 1.25 + 1e6


@given(seed=st.integers(0, 1000), sq=st.sampled_from([16, 33, 64]),
       hkv=st.sampled_from([1, 2, 4]), g=st.sampled_from([1, 2, 3]))
@settings(max_examples=10, deadline=None)
def test_flash_attention_matches_oracle_property(seed, sq, hkv, g):
    hq = hkv * g
    d = 8
    kq = jax.random.key(seed)
    ks = jax.random.split(kq, 3)
    q = jax.random.normal(ks[0], (1, sq, hq, d))
    k = jax.random.normal(ks[1], (1, sq, hkv, d))
    v = jax.random.normal(ks[2], (1, sq, hkv, d))
    want = ref.mha_reference(q, k, v, causal=True)
    got = kops.multi_head_attention(q, k, v, impl="flash", block_q=16,
                                    block_kv=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


@given(seed=st.integers(0, 100), n=st.sampled_from([100, 1000, 5000]),
       scale=st.floats(1e-4, 1e3))
@settings(**SETTINGS)
def test_int8_compression_error_bounded(seed, n, scale):
    x = jax.random.normal(jax.random.key(seed), (n,)) * scale
    y = compress_roundtrip(x, chunk=256)
    # per-chunk max error is scale_chunk/2 = max|x_chunk|/254
    err = np.max(np.abs(np.asarray(x - y)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127 + 1e-9


@given(b=st.integers(1, 3), t=st.integers(1, 30))
@settings(max_examples=10, deadline=None)
def test_rwkv_state_linearity(b, t):
    """The WKV recurrence is linear in the initial state."""
    h, n = 2, 4
    ks = jax.random.split(jax.random.key(t), 6)
    r = jax.random.normal(ks[0], (b, t, h, n)) * 0.5
    k = jax.random.normal(ks[1], (b, t, h, n)) * 0.5
    v = jax.random.normal(ks[2], (b, t, h, n)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n))) * 0.5 + 0.4
    u = jax.random.normal(ks[4], (h, n)) * 0.3
    s1 = jax.random.normal(ks[5], (b, h, n, n)) * 0.2
    o0, f0 = ref.rwkv6_reference(r, k, v, w, u, jnp.zeros_like(s1))
    o1, f1 = ref.rwkv6_reference(r, k, v, w, u, s1)
    o2, f2 = ref.rwkv6_reference(r, k, v, w, u, 2 * s1)
    np.testing.assert_allclose(np.asarray(o2 - o0),
                               np.asarray(2 * (o1 - o0)), atol=1e-4)
    np.testing.assert_allclose(np.asarray(f2 - f0),
                               np.asarray(2 * (f1 - f0)), atol=1e-4)


@given(shape_seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_kv_cache_bytes_monotone(shape_seed):
    rng = np.random.default_rng(shape_seed)
    spec = paper_model("llama3-8b")
    b = int(rng.integers(1, 64))
    tp_ = int(rng.integers(100, 10000))
    td = int(rng.integers(10, 2000))
    small = spec.kv_cache_bytes(b, tp_, td)
    bigger = spec.kv_cache_bytes(b + 1, tp_ + 100, td + 10)
    assert bigger > small
