"""Training substrate: optimizer, loop, checkpoint/restart fault tolerance,
determinism, straggler monitor, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import build_model
from repro.training.checkpoint import CheckpointManager
from repro.training.compression import (CompressionConfig, ErrorFeedback,
                                        compress_roundtrip)
from repro.training.fault import (FailureInjector, SimulatedFailure,
                                  StragglerMonitor, run_with_restarts)
from repro.training.optimizer import AdamWConfig, adamw, global_norm
from repro.training.train_loop import TrainConfig, Trainer

from conftest import tiny_dense_spec


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    opt = adamw(AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                            weight_decay=0.0))
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    state = opt.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_grad_clip_scales_global_norm():
    opt = adamw(AdamWConfig(grad_clip=1.0))
    grads = {"a": jnp.full((10,), 100.0)}
    assert float(global_norm(grads)) > 100


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_restartable():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=4, seed=3)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b5 = p1.batch_at(5)
    for _ in range(5):
        next(p2)
    b5b = next(p2)
    np.testing.assert_array_equal(b5["x"], b5b["x"])
    np.testing.assert_array_equal(b5["targets"], b5b["targets"])


def test_pipeline_shards_disjoint_rng():
    a = TokenPipeline(DataConfig(vocab=128, seq_len=16, global_batch=4,
                                 shard_id=0, num_shards=2))
    b = TokenPipeline(DataConfig(vocab=128, seq_len=16, global_batch=4,
                                 shard_id=1, num_shards=2))
    assert not np.array_equal(a.batch_at(0)["x"], b.batch_at(0)["x"])
    assert a.cfg.local_batch == 2


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    mgr.save(10, tree, extra={"note": "x"})
    out = mgr.restore(jax.eval_shape(lambda: tree))
    assert out is not None
    got, extra, step = out
    assert step == 10 and extra["note"] == "x"
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))


def test_checkpoint_gc_and_fallback_on_corruption(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.zeros((3,))}
    for s in (1, 2, 3):
        mgr.save(s, {"a": jnp.full((3,), float(s))})
    assert mgr.available_steps() == [2, 3]
    # corrupt the newest
    (mgr._step_dir(3) / "arrays.npz").write_bytes(b"garbage")
    got, _, step = mgr.restore(jax.eval_shape(lambda: tree))
    assert step == 2
    assert float(got["a"][0]) == 2.0


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(5, {"a": jnp.ones((8,))})
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"a": jnp.ones((2,))})
    for p in mgr.dir.glob("step_*"):
        assert (p / "COMMITTED").exists()


# ---------------------------------------------------------------------------
# end-to-end trainer + fault tolerance
# ---------------------------------------------------------------------------

def _make_trainer(tmp_path, spec, injector=None, steps_ck=5):
    model = build_model(spec, mesh=None, param_dtype=jnp.float32,
                        compute_dtype=jnp.float32)
    data_cfg = DataConfig(vocab=spec.vocab, seq_len=32, global_batch=8,
                          seed=0)
    cfg = TrainConfig(checkpoint_every=steps_ck,
                      checkpoint_dir=str(tmp_path / "ckpt"),
                      optimizer=AdamWConfig(lr=3e-3, warmup_steps=5,
                                            total_steps=60))
    return Trainer(model, data_cfg, cfg, rng=jax.random.key(0),
                   failure_injector=injector)


def test_loss_decreases(tmp_path):
    spec = tiny_dense_spec(vocab=64)
    tr = _make_trainer(tmp_path, spec)
    tr.run(0, 30)
    first = np.mean([h["loss"] for h in tr.history[:5]])
    last = np.mean([h["loss"] for h in tr.history[-5:]])
    assert last < first - 0.2, (first, last)


def test_restart_resumes_and_matches_uninterrupted(tmp_path):
    """Crash at step 12, restart, final params must equal a run that never
    crashed (bitwise determinism of data + donated-step math)."""
    spec = tiny_dense_spec(vocab=64)

    ref_tr = _make_trainer(tmp_path / "ref", spec)
    ref_tr.run(0, 20)
    ref_params = ref_tr.params

    injector = FailureInjector(fail_at_steps=(12,))
    attempts = []

    def make(attempt):
        attempts.append(attempt)
        return _make_trainer(tmp_path / "ft", spec, injector=injector)

    tr = run_with_restarts(make, total_steps=20)
    assert len(attempts) == 2  # one crash, one successful resume
    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(tr.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_restart_budget_exhaustion(tmp_path):
    spec = tiny_dense_spec(vocab=64)
    injector = FailureInjector(fail_at_steps=(2,))

    def make(attempt):
        injector.fired.clear()  # fails every attempt
        return _make_trainer(tmp_path / "loop", spec, injector=injector)

    with pytest.raises(RuntimeError, match="restart budget"):
        run_with_restarts(make, total_steps=10, max_restarts=2)


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=3.0)
    for i in range(20):
        mon.observe(i, 0.10 + 0.001 * (i % 3))
    assert mon.observe(20, 0.5)  # 5x step time -> straggler
    assert not mon.observe(21, 0.10)
    assert len(mon.flagged) == 1


def test_gradient_accumulation_matches_large_batch(tmp_path):
    spec = tiny_dense_spec(vocab=64)
    model = build_model(spec, mesh=None, param_dtype=jnp.float32,
                        compute_dtype=jnp.float32)
    data_cfg = DataConfig(vocab=64, seq_len=32, global_batch=8, seed=0)
    base = TrainConfig(checkpoint_dir=str(tmp_path / "a"),
                       optimizer=AdamWConfig(lr=1e-3, warmup_steps=0))
    acc = TrainConfig(checkpoint_dir=str(tmp_path / "b"), micro_batches=4,
                      optimizer=AdamWConfig(lr=1e-3, warmup_steps=0))
    t1 = Trainer(model, data_cfg, base, rng=jax.random.key(0))
    t2 = Trainer(model, data_cfg, acc, rng=jax.random.key(0))
    t1.run(0, 3)
    t2.run(0, 3)
    for a, b in zip(jax.tree.leaves(t1.params), jax.tree.leaves(t2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compression_error_feedback_recovers_mean():
    """With error feedback, the *accumulated* compressed signal tracks the
    true accumulated gradient (bias-free)."""
    ef = ErrorFeedback(CompressionConfig(chunk=64))
    g = {"w": jnp.full((256,), 0.003)}  # tiny values: heavy quantization
    sent_total = np.zeros(256)
    for _ in range(50):
        sent = ef(g)
        sent_total += np.asarray(sent["w"])
    np.testing.assert_allclose(sent_total, 50 * 0.003 * np.ones(256),
                               rtol=0.05)


def test_compression_wire_reduction():
    x = jax.random.normal(jax.random.key(0), (4096,))
    y = compress_roundtrip(x, chunk=1024)
    # int8 + f32 scale per 1024 elems = ~4x reduction; error small
    rel = float(jnp.linalg.norm(x - y) / jnp.linalg.norm(x))
    assert rel < 0.02
