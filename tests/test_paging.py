"""Paged KV-cache subsystem: allocator invariants, dense-vs-paged greedy
equivalence (the tentpole's token-identity acceptance), the capacity win
under a fixed HBM budget, preemption correctness, and the analytical
max-concurrency loop."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.serving import EngineConfig, PageAllocator, Request, ServeEngine
from repro.serving.paging import pages_for

from conftest import tiny_dense_spec


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

def test_allocator_basics():
    a = PageAllocator(n_pages=9, page_size=8)  # 8 usable, page 0 reserved
    assert a.usable_pages == 8 and a.free_pages == 8
    assert a.pages_for(1) == 1 and a.pages_for(8) == 1 and a.pages_for(9) == 2
    assert pages_for(0, 8) == 0
    assert a.ensure(owner=1, n_tokens=17)  # 3 pages
    assert a.pages_in_use == 3 and 0 not in a.owned(1)
    assert a.ensure(1, 17)  # idempotent
    assert a.pages_in_use == 3
    assert a.ensure(2, 33)  # 5 pages -> pool now full
    assert a.free_pages == 0
    assert not a.ensure(3, 1)  # all-or-nothing failure
    assert a.owned(3) == []
    a.check()
    assert a.release(1) == 3
    assert a.free_pages == 3
    assert a.ensure(3, 24)  # freed pages are reusable
    a.check()


def test_allocator_shortage_allocates_nothing():
    a = PageAllocator(n_pages=5, page_size=4)
    assert a.ensure(1, 8)  # 2 of 4 usable
    assert not a.ensure(2, 13)  # needs 4 > 2 free
    assert a.owned(2) == [] and a.free_pages == 2
    a.check()


def test_allocator_rejects_degenerate_pools():
    with pytest.raises(ValueError):
        PageAllocator(n_pages=1, page_size=8)
    with pytest.raises(ValueError):
        PageAllocator(n_pages=8, page_size=0)


# ---------------------------------------------------------------------------
# engine equivalence + capacity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    spec = tiny_dense_spec()
    model = build_model(spec, mesh=None, param_dtype=jnp.float32,
                        compute_dtype=jnp.float32)
    params = model.init(jax.random.key(7))
    return spec, model, params


def _serve(model, params, cfg, prompts, max_new=6):
    eng = ServeEngine(model, params, cfg)
    reqs = eng.serve([Request(prompt=list(p), max_new_tokens=max_new)
                      for p in prompts])
    assert all(r.state == "done" for r in reqs)
    return eng, [r.output for r in reqs]


def test_paged_equals_dense_mixed_prompt_lengths(served):
    """Acceptance: token-identical greedy outputs, dense vs paged, on a
    mixed prompt-length workload that exercises partial pages, page-
    boundary growth and slot churn."""
    spec, model, params = served
    rng = np.random.default_rng(3)
    lengths = [3, 11, 4, 17, 9, 5, 23, 8]
    prompts = [[int(t) for t in rng.integers(0, spec.vocab, size=n)]
               for n in lengths]
    _, dense = _serve(model, params,
                      EngineConfig(max_slots=4, max_seq=64, chunk_size=4,
                                   prefill_rows=3), prompts)
    peng, paged = _serve(model, params,
                         EngineConfig(max_slots=4, max_seq=64, chunk_size=4,
                                      prefill_rows=3, cache_layout="paged",
                                      page_size=8), prompts)
    assert dense == paged
    peng.pager.check()
    assert peng.metrics.pages_in_use_peak > 0
    assert 0 < peng.metrics.mean_kv_utilization <= 1


def test_paged_equals_dense_quantized(served):
    """The int8 k_scale/v_scale path pages alongside the values."""
    spec, _, _ = served
    model = build_model(spec, mesh=None, param_dtype=jnp.float32,
                        compute_dtype=jnp.float32, kv_quant=True)
    params = model.init(jax.random.key(7))
    prompts = [[5, 9, 2, 17, 33, 4, 8, 1], [7, 7, 7], [100, 3, 50, 2, 1]]
    _, dense = _serve(model, params,
                      EngineConfig(max_slots=3, max_seq=32, chunk_size=4),
                      prompts)
    _, paged = _serve(model, params,
                      EngineConfig(max_slots=3, max_seq=32, chunk_size=4,
                                   cache_layout="paged", page_size=8),
                      prompts)
    assert dense == paged


def test_paged_admits_strictly_more_under_same_budget(served):
    """Acceptance: under the same HBM token budget (4 slots x 64 tokens)
    the paged engine keeps strictly more requests decoding concurrently
    than the dense engine, because short requests stop stranding their
    max_seq reservation."""
    spec, model, params = served
    rng = np.random.default_rng(0)
    prompts = [[int(t) for t in rng.integers(0, spec.vocab, size=6)]
               for _ in range(16)]
    deng, dense = _serve(model, params,
                         EngineConfig(max_slots=4, max_seq=64, chunk_size=8,
                                      prefill_rows=4), prompts, max_new=4)
    peng, paged = _serve(model, params,
                         EngineConfig(max_slots=16, max_seq=64, chunk_size=8,
                                      prefill_rows=4, cache_layout="paged",
                                      page_size=8, n_pages=33),
                         prompts, max_new=4)
    assert dense == paged
    assert peng.metrics.peak_active > deng.metrics.peak_active
    # same budget: 32 usable pages x 8 tokens == 4 x 64 dense tokens
    assert (peng.pager.usable_pages * 8
            == deng.cfg.max_slots * deng.cfg.max_seq)


def test_preemption_keeps_greedy_outputs(served):
    """When the pool runs dry mid-decode the youngest active request is
    preempted and recomputed; greedy outputs must not change."""
    spec, model, params = served
    prompts = [[1 + i, 2, 3, 4, 5, 6, 7] for i in range(4)]
    cfg = EngineConfig(max_slots=6, max_seq=64, chunk_size=8,
                       prefill_rows=2, cache_layout="paged", page_size=8,
                       n_pages=9)  # 8 usable pages: too few for 4 requests
    peng, paged = _serve(model, params, cfg, prompts, max_new=20)
    assert peng.metrics.preemptions > 0
    peng.pager.check()
    _, dense = _serve(model, params,
                      EngineConfig(max_slots=6, max_seq=64, chunk_size=8,
                                   prefill_rows=2), prompts, max_new=20)
    assert paged == dense


def test_self_preemption_when_prefill_holds_the_pool(served):
    """A lone active request that cannot grow while an in-flight prefill's
    reservation holds the remaining pages must requeue itself (recompute)
    rather than truncate — outputs stay dense-identical, no capacity
    stop."""
    spec, model, params = served
    # 6 usable pages of 8 tokens.  A (8-token prompt; inserts positions
    # 8..16 while generating 10 tokens = 3 pages) decodes and grows page
    # by page while B's 30-token prompt crawls through a 2-token chunked
    # prefill holding a 4-page reservation: when A needs its third page
    # the pool is dry and the only other holder is not yet active.  Both
    # requests individually fit the pool, so no capacity stop is
    # legitimate.
    prompts = [[9, 8, 7, 6, 5, 4, 3, 2], list(range(1, 31))]
    cfg = EngineConfig(max_slots=2, max_seq=64, chunk_size=2,
                       prefill_rows=1, cache_layout="paged", page_size=8,
                       n_pages=7)
    peng, paged = _serve(model, params, cfg, prompts, max_new=10)
    assert peng.metrics.capacity_stops == 0
    assert peng.metrics.preemptions > 0
    _, dense = _serve(model, params,
                      EngineConfig(max_slots=2, max_seq=64, chunk_size=2,
                                   prefill_rows=1), prompts, max_new=10)
    assert paged == dense


def test_paged_rejects_oversized_prompt(served):
    spec, model, params = served
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=2, max_seq=32, chunk_size=8,
                                   cache_layout="paged", page_size=8,
                                   n_pages=3))  # 2 usable pages = 16 tokens
    with pytest.raises(ValueError, match="pages"):
        eng.submit(Request(prompt=list(range(1, 20)), max_new_tokens=2))
    # the per-slot page-table width binds even when the pool is plentiful:
    # max_seq=32 -> 4-entry rows; a 40-token prompt must be rejected, not
    # crash the insert after prefill
    eng2 = ServeEngine(model, params,
                       EngineConfig(max_slots=4, max_seq=32, chunk_size=8,
                                    cache_layout="paged", page_size=8,
                                    n_pages=17))  # 16 usable pages
    with pytest.raises(ValueError, match="max_pages"):
        eng2.submit(Request(prompt=list(range(1, 41)), max_new_tokens=2))


def test_paged_config_validation(served):
    spec, model, params = served
    with pytest.raises(ValueError, match="multiple"):
        ServeEngine(model, params,
                    EngineConfig(max_seq=30, cache_layout="paged",
                                 page_size=8))
    with pytest.raises(ValueError, match="cache_layout"):
        ServeEngine(model, params, EngineConfig(cache_layout="ragged"))


# ---------------------------------------------------------------------------
# analytical loop
# ---------------------------------------------------------------------------

def test_memory_check_paged_rounds_up_to_pages():
    from repro.core import Optimizations, ParallelismConfig, Workload
    from repro.core.stages import memory_check
    from repro.scenario import resolve_model, resolve_platform

    spec = resolve_model("llama3-8b")
    plat = resolve_platform("hgx-h100x8")
    wl = Workload(batch=8, tau_p=1000, tau_d=1, name="frag")
    par = ParallelismConfig()
    dense = memory_check(spec, plat, par, Optimizations(), wl)
    paged = memory_check(spec, plat, par,
                         Optimizations(paged_kv=True, kv_page_size=128), wl)
    # 1001 tokens -> 8 pages of 128 = 1024 tokens: paged >= dense, and the
    # gap is bounded by one page per request
    assert paged.kv_per_npu >= dense.kv_per_npu
    per_tok = dense.kv_per_npu / (wl.batch * 1001)
    assert paged.kv_per_npu - dense.kv_per_npu <= \
        wl.batch * 128 * per_tok + 1e-6


def test_max_concurrency_paged_beats_dense_reservation():
    from repro.core import Optimizations, ParallelismConfig, Workload
    from repro.core.stages import max_concurrency
    from repro.scenario import resolve_model, resolve_platform

    spec = resolve_model("llama3-8b")
    plat = resolve_platform("hgx-h100x8")
    wl = Workload(batch=1, tau_p=1024, tau_d=256, name="cap")
    par = ParallelismConfig(tp=8)
    dense = max_concurrency(spec, plat, par, Optimizations(), wl,
                            reserved_ctx=8192)  # dense engine's max_seq
    paged = max_concurrency(
        spec, plat, par, Optimizations(paged_kv=True, kv_page_size=64), wl)
    assert paged > dense > 0


def test_max_concurrency_req_budget_form_agrees():
    """The §VI budget-form helper matches the platform-form inversion when
    the whole platform is one unsharded pool (tp=ep=pp=1)."""
    from repro.core import Optimizations, ParallelismConfig, Workload
    from repro.core.requirements import max_concurrency_req
    from repro.core.stages import max_concurrency, _platform_capacity
    from repro.scenario import resolve_model, resolve_platform

    spec = resolve_model("llama3-8b")
    plat = resolve_platform("gb200x8")
    wl = Workload(batch=1, tau_p=2048, tau_d=512, name="cap")
    for opt in (Optimizations(),
                Optimizations(paged_kv=True, kv_page_size=128)):
        via_platform = max_concurrency(spec, plat, ParallelismConfig(),
                                       opt, wl)
        via_budget = max_concurrency_req(spec, wl, opt,
                                         _platform_capacity(plat))
        assert via_budget == via_platform > 0


def test_compare_reports_max_concurrency_error(served):
    """compare() ties the analytical §VI-A capacity prediction to the
    measured engine concurrency through the unified Report schema."""
    from repro.core.stages import Workload
    from repro.scenario import Scenario, compare, run, resolve_platform

    spec = tiny_dense_spec(name="cmp-tiny")
    wl = Workload(batch=16, tau_p=28, tau_d=4, name="cap")
    base = resolve_platform("hgx-h100x8")
    w_bytes = spec.param_count() * 2.0  # bf16 weights
    kv_budget = 5 * 32 * spec.kv_bytes_per_token("bf16")  # room for 5 reqs
    plat = dataclasses.replace(
        base, name="toy-cap",
        npu=dataclasses.replace(base.npu, mem=dataclasses.replace(
            base.npu.mem, capacity=w_bytes + kv_budget)))
    sc = Scenario.make(spec, workload=wl, platform=plat,
                       opt=dict(paged_kv=True, kv_page_size=8))
    pred, = run([sc], backend="analytical")
    assert pred.max_concurrency == 5
    meas, = run([sc], backend="engine",
                engine_kw=dict(max_slots=12, max_seq=64, max_prompt=28,
                               max_new=4, n_requests=16,
                               kv_budget_bytes=kv_budget))
    assert meas.status == "ok"
    assert meas.extra["kv"]["cache_layout"] == "paged"
    err = compare(pred, meas)
    assert "max_concurrency" in err and err["max_concurrency"] <= 0.25


def test_scenario_paged_opt_roundtrip_and_sweepable():
    from repro.core.stages import Workload
    from repro.scenario import Scenario, Sweep

    sc = Scenario.make(tiny_dense_spec(name="rt"),
                       workload=Workload(batch=2, tau_p=8, tau_d=4),
                       opt=dict(paged_kv=True, kv_page_size=32))
    assert Scenario.from_json(sc.to_json()) == sc
    grid = Sweep(sc).over(paged_kv=[False, True]).scenarios()
    assert [g.opt.paged_kv for g in grid] == [False, True]
