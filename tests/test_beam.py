"""Beam search (serving/beam.py) against a brute-force reference.

The reference recomputes every candidate's log-probabilities with a *full
forward pass over the whole prefix* — no KV cache, no row gather — and
mirrors BeamSearcher's selection rules (top-S_b distinct continuations of
beam 0 first, 2*S_b over-sampling for eos exits, length-penalty
normalization).  Agreement therefore validates exactly the machinery the
searcher adds: incremental decode against gathered-and-reordered cache
rows.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.serving.beam import BeamSearcher

from conftest import tiny_dense_spec


@pytest.fixture(scope="module")
def beam_model():
    spec = tiny_dense_spec()
    model = build_model(spec, mesh=None, param_dtype=jnp.float32,
                        compute_dtype=jnp.float32)
    params = model.init(jax.random.key(11))
    return spec, model, params


def _logp_next(model, params, tokens):
    """log-softmax over the next token, from a cache-free full forward."""
    logits = model.forward(params, jnp.asarray([tokens], jnp.int32))
    return np.asarray(
        jax.nn.log_softmax(logits[0, -1].astype(jnp.float32), -1))


def brute_force_beam(model, params, prompt, max_new, sb,
                     alpha=0.6, eos_id=None):
    """BeamSearcher semantics, recomputed from scratch each step."""
    lp = _logp_next(model, params, prompt)
    top = np.argsort(-lp)[:sb]
    beams = [[int(t)] for t in top]
    scores = lp[top]
    done = []
    for _ in range(max_new - 1):
        logps = np.stack([_logp_next(model, params, prompt + b)
                          for b in beams])
        joint = scores[:, None] + logps
        flat = joint.reshape(-1)
        order = np.argsort(-flat)[: 2 * sb]
        new_beams, new_scores = [], []
        for idx in order:
            b, t = divmod(int(idx), logps.shape[1])
            cand = beams[b] + [t]
            if eos_id is not None and t == eos_id:
                done.append((flat[idx] / len(cand) ** alpha, cand))
                continue
            new_beams.append(cand)
            new_scores.append(flat[idx])
            if len(new_beams) == sb:
                break
        if not new_beams:
            break
        beams, scores = new_beams, np.asarray(new_scores)
    for b, s in zip(beams, scores):
        done.append((s / len(b) ** alpha, b))
    done.sort(key=lambda x: -x[0])
    return done[0][1], float(done[0][0])


@pytest.mark.parametrize("sb", [2, 3])
def test_beam_matches_brute_force(beam_model, sb):
    spec, model, params = beam_model
    prompt = [5, 9, 2, 17, 33, 4]
    want_seq, want_score = brute_force_beam(model, params, prompt, 6, sb)
    searcher = BeamSearcher(model, params, beam_size=sb, max_seq=32)
    got_seq, got_score = searcher.search(list(prompt), 6)
    assert got_seq == want_seq
    np.testing.assert_allclose(got_score, want_score, atol=1e-4, rtol=1e-4)


def test_beam_with_eos_matches_brute_force(beam_model):
    spec, model, params = beam_model
    prompt = [7, 1, 3, 12]
    # pick an eos id the model actually emits early on some hypothesis so
    # the over-sampling / early-exit path is exercised
    probe, _ = brute_force_beam(model, params, prompt, 4, 3)
    eos = probe[1]
    want_seq, want_score = brute_force_beam(model, params, prompt, 6, 3,
                                            eos_id=eos)
    got_seq, got_score = BeamSearcher(model, params, beam_size=3,
                                      max_seq=32).search(list(prompt), 6,
                                                         eos_id=eos)
    assert got_seq == want_seq
    np.testing.assert_allclose(got_score, want_score, atol=1e-4, rtol=1e-4)


def test_beam_size_one_is_greedy(beam_model):
    spec, model, params = beam_model
    prompt = [5, 9, 2, 17]
    cache = model.init_cache(1, 32)
    logits, cache = model.prefill(params, jnp.asarray([prompt], jnp.int32),
                                  cache=cache)
    greedy = [int(jnp.argmax(logits[0]))]
    for _ in range(4):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[greedy[-1]]], jnp.int32))
        greedy.append(int(jnp.argmax(logits[0])))
    got_seq, _ = BeamSearcher(model, params, beam_size=1,
                              max_seq=32).search(list(prompt), 5)
    assert got_seq == greedy
