"""Disaggregated serving planner (beyond-paper: the paper's §IX future
work), built on the GenZ primitives."""

import pytest

from repro.core import GenZ, Optimizations, Workload, paper_model
from repro.core.disagg import colocated_goodput, plan_disaggregated


@pytest.fixture(scope="module")
def setup():
    g = GenZ.hgx_h100(8)
    platform = g.platform
    opt = Optimizations(weight_dtype="fp8", act_dtype="fp8", kv_dtype="fp8")
    return platform, opt


def test_planner_returns_feasible_plans(setup):
    platform, opt = setup
    wl = Workload(batch=1, tau_p=8192, tau_d=256, ttft_slo=2.0,
                  tpot_slo=0.05)
    plans = plan_disaggregated(paper_model("llama3-8b"), platform, wl, opt,
                               total_npus=8, tp_options=(1, 2, 4))
    assert plans, "no feasible disaggregated plan found"
    best = plans[0]
    assert best.total_npus <= 8
    assert best.goodput_rps > 0
    assert best.kv_transfer_s > 0  # disagg pays the KV hop
    assert best.meets_slo


def test_pool_sizing_balances_stages(setup):
    """The chosen split should not leave one stage >3x over-provisioned."""
    platform, opt = setup
    wl = Workload(batch=1, tau_p=8192, tau_d=512)
    plans = plan_disaggregated(paper_model("llama3-8b"), platform, wl, opt,
                               total_npus=16, tp_options=(1, 2, 4))
    best = plans[0]
    rate_p = best.n_prefill_groups / best.ttft
    rate_d = (best.n_decode_groups * best.decode_batch
              / (wl.tau_d * best.tpot))
    ratio = max(rate_p, rate_d) / min(rate_p, rate_d)
    assert ratio < 3.5, (rate_p, rate_d)


def test_disagg_beats_colocated_on_long_prompts(setup):
    """Long prompts + tight TPOT: fused chunked iterations stall decodes,
    disaggregation doesn't — the crossover the literature reports."""
    platform, opt = setup
    wl = Workload(batch=1, tau_p=16384, tau_d=256, tpot_slo=0.02)
    spec = paper_model("llama3-8b")
    plans = plan_disaggregated(spec, platform, wl, opt, total_npus=8,
                               tp_options=(1, 2, 4))
    co = colocated_goodput(spec, platform, wl, opt, total_npus=8, tp=4,
                           chunk=512)
    assert plans
    best = plans[0]
    assert best.tpot < co["tpot"], "disagg must decouple TPOT from prefill"
    assert best.meets_slo and not co["meets_slo"]


def test_kv_transfer_scales_with_prompt(setup):
    platform, opt = setup
    spec = paper_model("llama3-8b")
    short = plan_disaggregated(spec, platform,
                               Workload(batch=1, tau_p=1024, tau_d=128),
                               opt, total_npus=8, tp_options=(2,))
    long = plan_disaggregated(spec, platform,
                              Workload(batch=1, tau_p=16384, tau_d=128),
                              opt, total_npus=8, tp_options=(2,))
    assert long[0].kv_transfer_s > 10 * short[0].kv_transfer_s
