"""int8 KV cache (§Perf D3): quantize-on-insert / dequantize-on-read."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.models.attention import _dequantize_kv, _quantize_kv

from conftest import tiny_dense_spec


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(0), (2, 8, 4, 16)) * 3.0
    q, s = _quantize_kv(x)
    y = _dequantize_kv(q, s, jnp.float32)
    err = jnp.abs(x - y)
    bound = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-6
    assert bool(jnp.all(err <= bound))


@pytest.fixture(scope="module")
def pair():
    spec = tiny_dense_spec(d_model=128, n_heads=8, n_kv_heads=4, d_head=16)
    fp = build_model(spec, mesh=None, param_dtype=jnp.float32,
                     compute_dtype=jnp.float32)
    q8 = build_model(spec, mesh=None, param_dtype=jnp.float32,
                     compute_dtype=jnp.float32, kv_quant=True)
    params = fp.init(jax.random.key(0))
    return spec, fp, q8, params


def test_cache_dtype_and_size(pair):
    spec, fp, q8, params = pair
    c = q8.init_cache(2, 32)
    k = c.layers["pos0"].k
    assert k.dtype == jnp.int8
    assert c.layers["pos0"].k_scale is not None
    fp_bytes = sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(fp.init_cache(2, 32).layers))
    q8_bytes = sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(c.layers))
    assert q8_bytes < 0.45 * fp_bytes  # ~4x smaller vs the f32 test cache


def test_quantized_decode_tracks_full_precision(pair):
    spec, fp, q8, params = pair
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, spec.vocab)
    c1, c2 = fp.init_cache(2, 32), q8.init_cache(2, 32)
    l1, c1 = fp.prefill(params, toks, cache=c1)
    l2, c2 = q8.prefill(params, toks, cache=c2)
    assert float(jnp.max(jnp.abs(l1 - l2))) < 0.05
    for _ in range(6):
        t1 = jnp.argmax(l1, -1).astype(jnp.int32)[:, None]
        t2 = jnp.argmax(l2, -1).astype(jnp.int32)[:, None]
        assert bool((t1 == t2).all()), "greedy path diverged"
        l1, c1 = fp.decode_step(params, c1, t1)
        l2, c2 = q8.decode_step(params, c2, t2)


def test_quantized_chunked_prefill(pair):
    spec, fp, q8, params = pair
    toks = jax.random.randint(jax.random.key(2), (1, 12), 0, spec.vocab)
    c = q8.init_cache(1, 32)
    for lo in (0, 4, 8):
        logits, c = q8.prefill_chunk(params, c, toks[:, lo:lo + 4])
    want = fp.forward(params, toks)[:, -1]
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                               atol=0.05, rtol=0.05)
