"""Trace-replay workload generator: seeded determinism, JSON round trip,
multi-tenant template / multi-turn structure, bursty arrivals, and a live
replay through the serving engine with SLO/goodput accounting."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.models import build_model
from repro.serving import (EngineConfig, ServeEngine, TraceConfig,
                           generate_trace, replay, smoke_config,
                           trace_from_json, trace_to_json)

from conftest import tiny_dense_spec

CFG = TraceConfig(n_requests=24, seed=3)


def test_trace_deterministic_and_seed_sensitive():
    assert generate_trace(CFG) == generate_trace(CFG)
    assert generate_trace(CFG) != generate_trace(
        dataclasses.replace(CFG, seed=4))


def test_trace_json_round_trip():
    trace = generate_trace(CFG)
    assert trace_from_json(trace_to_json(trace, CFG)) == trace
    assert trace_from_json(trace_to_json(trace)) == trace  # config optional
    with pytest.raises(ValueError, match="version"):
        trace_from_json('{"version": 99, "requests": []}')


def test_trace_structure():
    trace = generate_trace(CFG)
    roots = [t for t in trace if t.parent is None]
    turns = [t for t in trace if t.parent is not None]
    assert len(roots) == CFG.n_requests
    assert turns, "multi_turn_p=0.4 over 24 roots should spawn follow-ups"
    arrivals = [t.arrival_s for t in roots]
    assert arrivals == sorted(arrivals) and arrivals[0] > 0.0
    # tenants share fixed templates: same template_id -> same token prefix
    by_tmpl: dict[str, tuple] = {}
    for t in roots:
        lo = CFG.template_tokens[0]
        head = t.prompt[:lo]
        assert by_tmpl.setdefault(t.template_id, head) == head
    assert len(by_tmpl) == CFG.n_tenants * CFG.templates_per_tenant
    for t in turns:
        parent = trace[t.parent]
        assert t.turn == parent.turn + 1 <= CFG.max_turns
        assert t.tenant == parent.tenant
        assert t.arrival_s > parent.arrival_s  # lands after + think time
        assert len(t.prompt) < CFG.suffix_tokens[1]  # new-turn tokens only


def test_plain_poisson_degenerates_at_burst_factor_one():
    cfg = dataclasses.replace(CFG, burst_factor=1.0)
    trace = generate_trace(cfg)
    assert len([t for t in trace if t.parent is None]) == cfg.n_requests


def test_smoke_config_shrinks():
    small = smoke_config(CFG)
    assert small.n_requests < CFG.n_requests
    assert small.seed == CFG.seed  # the driving seed is preserved
    assert len(generate_trace(small)) < len(generate_trace(CFG))


def test_replay_on_engine_reports_slo_and_goodput():
    spec = tiny_dense_spec()
    model = build_model(spec, mesh=None, param_dtype=jnp.float32,
                        compute_dtype=jnp.float32)
    params = model.init(jax.random.key(7))
    eng = ServeEngine(model, params,
                      EngineConfig(max_slots=3, max_seq=64, chunk_size=8,
                                   prefill_rows=2, cache_layout="paged",
                                   page_size=8, unified=True),
                      rng=jax.random.key(1))
    trace = generate_trace(smoke_config(TraceConfig(seed=0, vocab=spec.vocab)))
    summary, reqs = replay(eng, trace, ttft_slo_s=30.0, tpot_slo_s=30.0)
    assert all(r.state == "done" for r in reqs)
    assert summary.n_requests == len(trace)
    assert summary.throughput_tok_s > 0
    assert 0.0 <= summary.slo_attainment <= 1.0
    # generous SLOs on a tiny model: everything attains, goodput == thrpt
    assert summary.slo_attainment == 1.0
    assert summary.goodput_tok_s == summary.throughput_tok_s
    assert summary.engine["requests_done"] == len(trace)
    assert set(summary.by_tenant) == {t.tenant for t in trace}
    for tally in summary.by_tenant.values():
        assert tally["attained"] == tally["requests"]
    # continuations decoded with their parent's full history as context
    for i, t in enumerate(trace):
        if t.parent is not None:
            par = reqs[t.parent]
            want = list(par.prompt) + list(par.output) + list(t.prompt)
            assert reqs[i].prompt == want
