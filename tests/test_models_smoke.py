"""Per-architecture smoke tests (deliverable f): every assigned arch at a
REDUCED config runs one forward + one train step on CPU, asserting output
shapes and no NaNs; prefill/decode consistency for decoder archs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import build_model

ARCHS = list(registry.ARCH_IDS)


def _inputs(spec, B=2, S=16, seed=2):
    if spec.frontend != "none":
        return None, jax.random.normal(jax.random.key(seed),
                                       (B, S, spec.d_model), jnp.float32)
    return jax.random.randint(jax.random.key(seed), (B, S), 0,
                              spec.vocab), None


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            spec = registry.get_reduced(arch)
            model = build_model(spec, mesh=None, param_dtype=jnp.float32,
                                compute_dtype=jnp.float32)
            params = model.init(jax.random.key(1))
            cache[arch] = (spec, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch, built):
    spec, model, params = built(arch)
    B, S = 2, 16
    tokens, embeds = _inputs(spec, B, S)
    logits = jax.jit(
        lambda p: model.forward(p, tokens, embeds=embeds))(params)
    assert logits.shape == (B, S, spec.vocab)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite_grads(arch, built):
    spec, model, params = built(arch)
    B, S = 2, 16
    tokens, embeds = _inputs(spec, B, S)
    targets = jax.random.randint(jax.random.key(3), (B, S), 0, spec.vocab)

    def loss_fn(p):
        return model.loss(p, tokens, targets, embeds=embeds, chunk=8)

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    assert abs(float(loss) - np.log(spec.vocab)) < 1.0  # random init
    for g in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(g, np.float32)))


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if registry.get_spec(a).decoder])
def test_prefill_matches_forward(arch, built):
    spec, model, params = built(arch)
    B, S = 2, 12
    tokens, embeds = _inputs(spec, B, S)
    logits = model.forward(params, tokens, embeds=embeds)
    cache = model.init_cache(B, 32)
    if embeds is not None:
        last, cache = model.prefill(params, embeds=embeds, cache=cache)
    else:
        last, cache = model.prefill(params, tokens, cache=cache)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits[:, -1]), atol=2e-3,
                               rtol=1e-3)


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if registry.get_spec(a).decoder
                                  and registry.get_spec(a).frontend == "none"])
def test_decode_matches_teacher_forcing(arch, built):
    """Decoding token-by-token must equal the full forward pass."""
    spec, model, params = built(arch)
    B, S = 1, 10
    tokens, _ = _inputs(spec, B, S)
    full = model.forward(params, tokens)
    cache = model.init_cache(B, 32)
    _, cache = model.prefill(params, tokens[:, :4], cache=cache)
    for i in range(4, S):
        logits, cache = model.decode_step(params, cache, tokens[:, i:i + 1])
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, i]), atol=3e-3,
                                   rtol=1e-3,
                                   err_msg=f"{arch} step {i}")


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if registry.get_spec(a).decoder
                                  and registry.get_spec(a).frontend == "none"])
def test_chunked_prefill_matches_full_prefill(arch, built):
    """Paper §IV-A: chunked prefill must be numerically equivalent."""
    spec, model, params = built(arch)
    B, S = 2, 12
    tokens, _ = _inputs(spec, B, S)
    c1 = model.init_cache(B, 32)
    full_logits, c1 = model.prefill(params, tokens, cache=c1)
    c2 = model.init_cache(B, 32)
    for lo in (0, 4, 8):
        chunk_logits, c2 = model.prefill_chunk(params, c2,
                                               tokens[:, lo:lo + 4])
    np.testing.assert_allclose(np.asarray(chunk_logits),
                               np.asarray(full_logits), atol=3e-3,
                               rtol=1e-3)
    assert int(c2.lengths[0]) == S


def test_full_configs_instantiable_as_specs():
    """FULL configs are exercised via dry-run only; here we check the
    published numbers are wired exactly."""
    q = registry.get_spec("qwen1.5-0.5b")
    assert (q.d_model, q.n_layers, q.n_heads, q.d_ff, q.vocab) == \
        (1024, 24, 16, 2816, 151936)
    assert q.qkv_bias and q.tied_embeddings
    y = registry.get_spec("yi-34b")
    assert (y.d_model, y.n_layers, y.n_heads, y.n_kv_heads) == \
        (7168, 60, 56, 8)
    dm = registry.get_spec("deepseek-moe-16b")
    assert dm.moe.num_experts == 64 and dm.moe.top_k == 6
    assert dm.moe.shared_experts == 2
    j = registry.get_spec("jamba-v0.1-52b")
    kinds = j.layer_kinds()
    assert kinds.count("attn") == 4 and kinds.count("ssm") == 28
    assert len(j.moe_layer_indices()) == 16
    r = registry.get_spec("rwkv6-3b")
    assert r.is_attention_free and r.supports_long_context
    h = registry.get_spec("hubert-xlarge")
    assert not h.decoder and h.frontend == "audio"
    p = registry.get_spec("pixtral-12b")
    assert p.frontend == "vision" and p.d_head == 128
